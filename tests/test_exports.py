"""Every public name in `repro.serve` must actually resolve.

`repro.serve` lazy-loads its exports through PEP 562 `__getattr__`
routed by an `_EXPORT_HOMES` table — a name added to `__all__` without
a matching home entry (or pointing at a symbol its home module no
longer defines) imports fine and then explodes at first use. This
regression walks the full surface so the break is caught here instead.
"""
import importlib


def test_every_serve_export_resolves():
    serve = importlib.import_module("repro.serve")
    assert serve.__all__ == sorted(set(serve.__all__))
    for name in serve.__all__:
        obj = getattr(serve, name)
        assert obj is not None, name


def test_dir_covers_all():
    serve = importlib.import_module("repro.serve")
    missing = set(serve.__all__) - set(dir(serve))
    assert not missing


def test_multi_tenant_names_are_exported():
    serve = importlib.import_module("repro.serve")
    for name in ("build_multi_tenant_pipeline", "compile_multi_tenant",
                 "MultiTenantBundlePoint"):
        assert name in serve.__all__

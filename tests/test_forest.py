"""Histogram forest trainer: correctness + hypothesis property tests."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # seeded-sampling fallback, see tests/_hypothesis_shim.py
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.forest import (
    forest_apply_np, forest_predict_class, forest_predict_value,
    train_forest, train_tree,
)


def test_tree_fits_separable(rng):
    X = rng.standard_normal((2000, 8)).astype(np.float32)
    y = (X[:, 2] > 0.3).astype(int)
    t = train_tree(X[:1500], y[:1500], max_depth=4)
    acc = (forest_predict_class(t, X[1500:]) == y[1500:]).mean()
    assert acc > 0.97


def test_forest_beats_chance_multiclass(rng):
    K = 6
    centers = rng.normal(0, 3, (K, 10))
    y = rng.integers(0, K, 3000)
    X = (centers[y] + rng.normal(0, 1.0, (3000, 10))).astype(np.float32)
    f = train_forest(X[:2400], y[:2400], n_trees=15, max_depth=8)
    acc = (forest_predict_class(f, X[2400:]) == y[2400:]).mean()
    assert acc > 0.9


def test_regression_r2(rng):
    X = rng.standard_normal((2000, 6)).astype(np.float32)
    y = 2 * X[:, 0] - X[:, 1] ** 2
    f = train_forest(X[:1600], y[:1600], n_trees=20, max_depth=8,
                     classification=False, max_features=None)
    pred = forest_predict_value(f, X[1600:])
    r2 = 1 - np.mean((pred - y[1600:]) ** 2) / np.var(y[1600:])
    assert r2 > 0.8


def test_dense_layout_invariants(rng):
    X = rng.standard_normal((500, 5)).astype(np.float32)
    y = rng.integers(0, 3, 500)
    f = train_forest(X, y, n_trees=5, max_depth=6)
    assert f.feature.shape == (5, 2 ** 6 - 1)
    assert f.leaf.shape == (5, 2 ** 6, 3)
    # features in range; pass-through slots have +inf thresholds
    assert (f.feature >= 0).all() and (f.feature < 5).all()
    live = np.isfinite(f.threshold)
    assert live.any()
    # class histograms in leaves are distributions (or a fill value)
    sums = f.leaf.sum(-1)
    assert np.all(sums > 0.99)


def test_probability_output_normalized(rng):
    X = rng.standard_normal((400, 4)).astype(np.float32)
    y = rng.integers(0, 4, 400)
    f = train_forest(X, y, n_trees=8, max_depth=5)
    probs = forest_apply_np(f, X)
    np.testing.assert_allclose(probs.sum(1), 1.0, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(50, 300),
    f_dim=st.integers(2, 8),
    k=st.integers(2, 5),
    depth=st.integers(2, 6),
    seed=st.integers(0, 1000),
)
def test_property_training_never_crashes_and_predicts_valid_classes(
    n, f_dim, k, depth, seed
):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, f_dim)).astype(np.float32)
    y = rng.integers(0, k, n)
    f = train_forest(X, y, n_trees=3, max_depth=depth,
                     rng=np.random.default_rng(seed))
    pred = forest_predict_class(f, X)
    assert set(np.unique(pred)) <= set(np.unique(y))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100))
def test_property_constant_labels_predict_constant(seed):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((100, 3)).astype(np.float32)
    y = np.full(100, 7)
    f = train_forest(X, y, n_trees=3, max_depth=4)
    assert (forest_predict_class(f, X) == 7).all()


def test_feature_importance_identifies_signal(rng):
    X = rng.standard_normal((2000, 10)).astype(np.float32)
    y = (X[:, 4] + 0.3 * X[:, 7] > 0).astype(int)
    f = train_forest(X, y, n_trees=10, max_depth=6, max_features=None)
    imp = f.feature_importance()
    assert imp[4] == imp.max()

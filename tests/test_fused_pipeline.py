"""Fused single-launch pipeline: bit-parity and kernel padding contracts.

The fused Pallas kernel (`repro.kernels.fused_pipeline`) must be
bit-identical to the two-launch path for every feature family, connection
depth, and batch geometry — that is the DESIGN.md §7 contract that lets the
serving runtime switch to one launch without revalidating the model. Also
covers the block-padding satellite: `flow_stats_kernel_call` and
`forest_infer_kernel_call` accept arbitrary (non-block-multiple) sizes
directly, with no assert to lose under ``python -O``.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.search_space import FeatureRep
from repro.kernels import ref
from repro.kernels.feature_extract import flow_stats_kernel_call
from repro.kernels.tree_infer import forest_infer_kernel_call
from repro.traffic import FEATURE_NAMES, extract_features, make_dataset
from repro.traffic.extraction import stats_plan
from repro.traffic.models import train_traffic_model
from repro.traffic.pipeline import build_pipeline

R = np.random.default_rng(7)

# one representative per op family the emitter knows: durations, metadata,
# loads, counts, handshake timings, flag counters, and every stat over
# bytes/iat/winsize/ttl including the sorting (median) and two-pass (std)
FEATURE_SUBSETS = [
    ("dur", "proto", "s_port", "d_port"),
    ("s_load", "d_load", "s_pkt_cnt", "d_pkt_cnt"),
    ("tcp_rtt", "syn_ack", "ack_dat", "syn_cnt", "ack_cnt", "fin_cnt"),
    ("s_bytes_sum", "s_bytes_mean", "s_bytes_min", "s_bytes_max",
     "s_bytes_med", "s_bytes_std"),
    ("d_iat_mean", "d_iat_std", "d_iat_med", "s_iat_min", "s_iat_max"),
    ("s_winsize_mean", "d_winsize_std", "s_ttl_min", "d_ttl_max",
     "d_winsize_med"),
]


@pytest.fixture(scope="module")
def ds():
    # 257 flows: exercises flow-axis padding in every launch geometry
    return make_dataset("app-class", n_flows=257, max_pkts=16, seed=11)


def _forest(ds, rep, model="rf-fast"):
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model=model, seed=0)
    return forest


@pytest.mark.parametrize("features", FEATURE_SUBSETS)
@pytest.mark.parametrize("depth", [4, 12])
def test_fused_bit_identical_to_unfused(ds, features, depth):
    rep = FeatureRep(features, depth=depth)
    forest = _forest(ds, rep)
    unfused = build_pipeline(rep, forest, ds.max_pkts, use_kernel=True)
    fused = build_pipeline(rep, forest, ds.max_pkts, fused=True)
    pu = unfused.probabilities(ds)
    pf = fused.probabilities(ds)
    assert np.array_equal(pu, pf), "fused probabilities diverged bitwise"
    assert np.array_equal(unfused(ds), fused(ds))


def test_fused_parity_full_feature_set(ds):
    """All 67 registry features through the fused kernel at once."""
    rep = FeatureRep(tuple(FEATURE_NAMES), depth=10)
    forest = _forest(ds, rep, model="tree-fast")
    unfused = build_pipeline(rep, forest, ds.max_pkts, use_kernel=True)
    fused = build_pipeline(rep, forest, ds.max_pkts, fused=True)
    assert np.array_equal(unfused.probabilities(ds), fused.probabilities(ds))


@pytest.mark.parametrize("n", [1, 5, 8, 37, 130])
def test_fused_arbitrary_batch_sizes(ds, n):
    """Bucket-shaped and ragged batch sizes all stay bit-identical."""
    rep = FeatureRep(("dur", "s_load", "s_bytes_mean", "d_iat_std"), depth=8)
    forest = _forest(ds, rep)
    unfused = build_pipeline(rep, forest, ds.max_pkts, use_kernel=True)
    fused = build_pipeline(rep, forest, ds.max_pkts, fused=True)
    sub = ds.take(np.arange(n))
    assert np.array_equal(unfused.probabilities(sub), fused.probabilities(sub))


def test_fused_predictions_match_ref_path(ds):
    """Vote accumulation order differs from the jnp reference by ulps at
    most — class predictions must still agree."""
    rep = FeatureRep(("dur", "s_load", "s_bytes_mean", "ack_cnt"), depth=8)
    forest = _forest(ds, rep)
    ref_pipe = build_pipeline(rep, forest, ds.max_pkts, use_kernel=False)
    fused = build_pipeline(rep, forest, ds.max_pkts, fused=True)
    np.testing.assert_allclose(
        fused.probabilities(ds), ref_pipe.probabilities(ds), atol=1e-5)
    assert np.array_equal(fused(ds), ref_pipe(ds))


def test_stats_plan_static_and_total():
    """The plan is hashable (a jit static arg), order-preserving, and
    rejects unknown features."""
    plan = stats_plan(("dur", "s_bytes_med", "ack_cnt", "d_load"))
    assert isinstance(hash(plan), int)
    assert plan[0] == ("dur",) and plan[3] == ("load", "d")
    assert len(stats_plan(FEATURE_NAMES)) == 67
    with pytest.raises(ValueError):
        stats_plan(("nope_bytes_gm",))


# ---------------------------------------------------------------------------
# kernel-call padding (satellite): direct calls, no ops.py pre-padding
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,P,bn", [(73, 17, 32), (5, 8, 512), (256, 12, 64)])
def test_flow_stats_kernel_pads_flow_axis(n, P, bn):
    v = jnp.asarray(R.standard_normal((n, P)), jnp.float32)
    m = jnp.asarray(R.random((n, P)) < 0.4)
    got = flow_stats_kernel_call(v, m, block_n=bn, interpret=True)
    assert got.shape == (n, 5)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.flow_stats_ref(v, m)), atol=1e-5)


@pytest.mark.parametrize("n,T,bn,bt", [(77, 5, 32, 4), (130, 3, 128, 8),
                                       (9, 12, 256, 5)])
def test_forest_kernel_pads_both_axes(n, T, bn, bt):
    depth, F, K = 4, 6, 3
    feature = jnp.asarray(R.integers(0, F, (T, 2 ** depth - 1)), jnp.int32)
    threshold = jnp.asarray(R.standard_normal((T, 2 ** depth - 1)), jnp.float32)
    leaf = jnp.asarray(R.random((T, 2 ** depth, K)), jnp.float32)
    x = jnp.asarray(R.standard_normal((n, F)), jnp.float32)
    got = forest_infer_kernel_call(
        x, feature, threshold, leaf, depth, block_n=bn, block_t=bt,
        interpret=True)
    assert got.shape == (n, K)
    want = ref.forest_infer_ref(x, feature, threshold, leaf, depth)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

"""HLO text analyzer unit tests (pure parsing — no compilation needed)."""

from repro.launch.hlo_stats import hlo_stats

HLO = """
HloModule jit_f

%fused_computation (p0: f32[128,64]) -> f32[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  ROOT %neg = f32[128,64]{1,0} negate(%p0)
}

%wide.body (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg = (s32[], f32[8,8]) parameter(0)
  %c = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%arg), index=1
  %y = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%c, %y)
}

ENTRY %main (a: f32[128,256], b: f32[256,64]) -> f32[128,64] {
  %a = f32[128,256]{1,0} parameter(0)
  %b = f32[256,64]{1,0} parameter(1)
  %d = f32[128,64]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,64]{1,0} all-reduce(%d), replica_groups={}, to_apply=%sum
  %ag = f32[128,128]{1,0} all-gather(%ar), dimensions={1}
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%wide.body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[128,64]{1,0} fusion(%ar), kind=kLoop, calls=%fused_computation
}
"""


def test_dot_flops_exact():
    st = hlo_stats(HLO)
    # entry dot: 2*128*64*256 ; while-body dot: 2*8*8*8 * trip 10
    want = 2 * 128 * 64 * 256 + 10 * 2 * 8 * 8 * 8
    assert st["flops"] == want, (st["flops"], want)
    assert st["n_dots"] == 2


def test_collectives_counted_with_allreduce_doubling():
    st = hlo_stats(HLO)
    ar = st["collectives"]["all-reduce"]
    ag = st["collectives"]["all-gather"]
    assert ar == 2 * 128 * 64 * 4   # payload x2
    assert ag == 128 * 128 * 4      # gathered result size
    assert st["collectives"]["count"] == 2


def test_bytes_traffic_positive_and_sane():
    st = hlo_stats(HLO)
    assert st["bytes"] > 128 * 256 * 4  # at least the big dot's operands

"""Vectorized ingest contracts: observe_batch ≡ observe, block flush timing
≡ per-packet flush timing, chunked replay ≡ the per-packet reference loop,
and staging-arena/donation safety under double-buffered dispatch.

These are the DESIGN.md §7 exactness guarantees: the fast path is a
performance rewrite, not a semantics change, so every comparison below is
equality (bitwise for table state and predictions), with latency allowed
float tolerance only where the vectorized Lindley recurrence reassociates
the scalar max-chain.
"""

import numpy as np
import pytest

from repro.core.search_space import FeatureRep
from repro.serve.runtime import (
    FlowStatus,
    FlowTable,
    PacketStream,
    RuntimeMetrics,
    ServiceModel,
    StreamingRuntime,
    replay,
)
from repro.traffic import extract_features, make_dataset
from repro.traffic.models import train_traffic_model
from repro.traffic.pipeline import build_pipeline

DEPTH = 6


@pytest.fixture(scope="module")
def ds():
    return make_dataset("app-class", n_flows=300, max_pkts=24, seed=9)


@pytest.fixture(scope="module")
def stream(ds):
    return PacketStream.from_dataset(ds, seed=1)


@pytest.fixture(scope="module")
def pipeline(ds):
    rep = FeatureRep(
        ("dur", "s_load", "s_bytes_mean", "d_iat_std", "ack_cnt"), depth=DEPTH)
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="rf-fast", seed=0)
    return build_pipeline(rep, forest, max_pkts=rep.depth, fused=True)


def _pkt_arrays(stream, lo, hi):
    fid = stream.fid[lo:hi]
    return dict(
        key=stream.key[fid], now=stream.base_t[lo:hi],
        rel_ts=stream.rel_ts32[lo:hi], size=stream.size[lo:hi],
        direction=stream.direction[lo:hi], ttl=stream.ttl[lo:hi],
        winsize=stream.winsize[lo:hi], flags_byte=stream.flags_byte[lo:hi],
        proto=stream.proto[fid], s_port=stream.s_port[fid],
        d_port=stream.d_port[fid], flow_id=fid, fin=stream.fin[lo:hi],
    )


def _drive_table(stream, *, capacity, pkt_depth, chunk, evict_at=()):
    """Feed the whole stream through a fresh table; chunk=0 -> scalar path."""
    ft = FlowTable(capacity, pkt_depth, idle_timeout_s=5.0,
                   metrics=RuntimeMetrics())
    E = stream.n_events
    evict_at = set(evict_at)
    if chunk == 0:
        for i in range(E):
            a = _pkt_arrays(stream, i, i + 1)
            ft.observe(int(a["key"][0]), float(a["now"][0]),
                       float(a["rel_ts"][0]), float(a["size"][0]),
                       int(a["direction"][0]), float(a["ttl"][0]),
                       float(a["winsize"][0]), int(a["flags_byte"][0]),
                       float(a["proto"][0]), float(a["s_port"][0]),
                       float(a["d_port"][0]), int(a["flow_id"][0]),
                       bool(a["fin"][0]))
            if i + 1 in evict_at:
                ft.evict_idle(float(a["now"][0]))
    else:
        for lo in range(0, E, chunk):
            hi = min(lo + chunk, E)
            a = _pkt_arrays(stream, lo, hi)
            ft.observe_batch(
                a["key"], a["now"], a["rel_ts"], a["size"], a["direction"],
                a["ttl"], a["winsize"], a["flags_byte"], a["proto"],
                a["s_port"], a["d_port"], a["flow_id"], a["fin"])
            for j in range(lo + 1, hi + 1):
                if j in evict_at:
                    ft.evict_idle(float(stream.base_t[j - 1]))
        # chunked eviction points must land on block boundaries to compare
    return ft


def _assert_tables_equal(a: FlowTable, b: FlowTable):
    assert (a.ctrl == b.ctrl).all()
    for f in ("ts", "size", "direction", "ttl", "winsize", "flags",
              "proto", "s_port", "d_port"):
        assert (getattr(a, f) == getattr(b, f)).all(), f
    assert a._free == b._free
    assert (a._buckets == b._buckets).all()
    assert a.metrics.summary() == b.metrics.summary()


@pytest.mark.parametrize("chunk", [1, 17, 256])
def test_observe_batch_state_equivalence(stream, chunk):
    """Full-stream table state is bitwise identical to the scalar loop for
    any chunking — payload, control block, hash index, free-list order,
    and metrics."""
    scalar = _drive_table(stream, capacity=512, pkt_depth=DEPTH, chunk=0)
    batch = _drive_table(stream, capacity=512, pkt_depth=DEPTH, chunk=chunk)
    _assert_tables_equal(scalar, batch)


def test_observe_batch_equivalence_under_overflow(stream):
    """A undersized table sheds flows; drop decisions (allocation order vs
    free-list state) must sequence exactly as the scalar path."""
    scalar = _drive_table(stream, capacity=24, pkt_depth=DEPTH, chunk=0)
    batch = _drive_table(stream, capacity=24, pkt_depth=DEPTH, chunk=64)
    assert scalar.metrics.drops_table > 0
    _assert_tables_equal(scalar, batch)


def test_observe_batch_equivalence_with_eviction(stream):
    """Idle eviction interleaved at chunk boundaries stays equivalent
    (evicted ACTIVE flows -> READY; PREDICTED reclaim; re-tenancy after)."""
    pts = (512, 1024, 2048)
    scalar = _drive_table(stream, capacity=256, pkt_depth=DEPTH, chunk=0,
                          evict_at=pts)
    batch = _drive_table(stream, capacity=256, pkt_depth=DEPTH, chunk=256,
                         evict_at=pts)
    _assert_tables_equal(scalar, batch)


def test_observe_batch_fin_close_and_retenancy_in_one_block():
    """The adversarial slow-path block: a flow completes, is marked
    PREDICTED, then within a single observe_batch block receives its
    bidirectional FIN close AND a re-tenancy of the same 5-tuple — the
    scalar interleaving (recycle before re-alloc) must be preserved."""
    def build(batch: bool):
        ft = FlowTable(4, pkt_depth=2, metrics=RuntimeMetrics())
        # fill to depth -> READY -> PREDICTED
        for i, t in enumerate((0.0, 0.1)):
            ft.observe(7, t, t, 100.0, i % 2, 64.0, 1000.0, 0x10,
                       6.0, 1.0, 2.0, 0, False)
        slot = ft._probe(7)[0]
        ft.mark_predicted(np.array([slot]))
        # block: FIN fwd, FIN rev (-> CLOSED, recycle), then the same key
        # returns (re-tenancy: must allocate a fresh tenancy, new flow_id)
        k = np.full(3, 7, np.uint64)
        t = np.array([0.2, 0.3, 0.4])
        dirn = np.array([0, 1, 0], np.uint8)
        fin = np.array([True, True, False])
        fids = np.array([0, 0, 1])
        args = (k, t, t.astype(np.float32), np.full(3, 99.0, np.float32),
                dirn, np.full(3, 64.0, np.float32),
                np.full(3, 1000.0, np.float32), np.full(3, 0x11, np.uint8),
                np.full(3, 6.0, np.float32), np.full(3, 1.0, np.float32),
                np.full(3, 2.0, np.float32), fids, fin)
        if batch:
            st, sl, acc = ft.observe_batch(*args)
        else:
            st = np.empty(3, np.uint8)
            sl = np.empty(3, np.int64)
            for i in range(3):
                s, q = ft.observe(int(k[i]), float(t[i]), float(t[i]), 99.0,
                                  int(dirn[i]), 64.0, 1000.0, 0x11, 6.0, 1.0,
                                  2.0, int(fids[i]), bool(fin[i]))
                st[i], sl[i] = int(s), q
        return ft, st, sl

    ft_s, st_s, sl_s = build(batch=False)
    ft_b, st_b, sl_b = build(batch=True)
    assert (st_s == st_b).all() and (sl_s == sl_b).all()
    _assert_tables_equal(ft_s, ft_b)
    assert st_s[1] == int(FlowStatus.CLOSED)          # bidirectional close
    assert st_s[2] == int(FlowStatus.TRACKED)          # fresh tenancy
    assert ft_b.ctrl["flow_id"][sl_b[2]] == 1


def test_ingest_packets_flush_timing_equivalence(pipeline, stream):
    """Block ingest fires the same flushes (order, reason, now, members)
    as the per-packet cadence, including timeout flushes triggered by
    packets that enqueue nothing."""
    def run(block: int):
        rt = StreamingRuntime(pipeline, capacity=1024, max_batch=32,
                              min_bucket=8, flush_timeout_s=0.02,
                              execute=False)
        E = stream.n_events
        if block == 0:
            for i in range(E):
                a = _pkt_arrays(stream, i, i + 1)
                rt.ingest_packet(
                    int(a["key"][0]), float(a["now"][0]), float(a["rel_ts"][0]),
                    float(a["size"][0]), int(a["direction"][0]),
                    float(a["ttl"][0]), float(a["winsize"][0]),
                    int(a["flags_byte"][0]), float(a["proto"][0]),
                    float(a["s_port"][0]), float(a["d_port"][0]),
                    int(a["flow_id"][0]), bool(a["fin"][0]))
        else:
            for lo in range(0, E, block):
                hi = min(lo + block, E)
                a = _pkt_arrays(stream, lo, hi)
                rt.ingest_packets(
                    a["key"], a["now"], a["rel_ts"], a["size"],
                    a["direction"], a["ttl"], a["winsize"], a["flags_byte"],
                    a["proto"], a["s_port"], a["d_port"], a["flow_id"],
                    a["fin"])
        rt.drain(float(stream.base_t[-1]) + 1.0)
        return rt.dispatcher.records

    want = run(0)
    got = run(200)
    assert len(want) == len(got)
    for w, g in zip(want, got):
        assert (w.bucket, w.n_real, w.reason, w.flush_ts) == \
            (g.bucket, g.n_real, g.reason, g.flush_ts)
        assert (w.flow_ids == g.flow_ids).all()
        assert (w.ready_ts == g.ready_ts).all()


def test_ingest_packets_equivalent_under_table_pressure(pipeline, stream):
    """Flush side effects land mid-block: with a tiny table and small
    max_batch, full flushes recycle closed flows' slots while the block is
    still streaming in — drop accounting and re-tenancy must still match
    the per-packet cadence exactly (the sub-block bound pins every flush
    to the packet that triggered it)."""
    def run(block: int):
        rt = StreamingRuntime(pipeline, capacity=16, max_batch=8,
                              min_bucket=8, flush_timeout_s=0.02,
                              execute=False)
        E = stream.n_events
        step = block if block else 1
        for lo in range(0, E, step):
            hi = min(lo + step, E)
            a = _pkt_arrays(stream, lo, hi)
            if block:
                rt.ingest_packets(
                    a["key"], a["now"], a["rel_ts"], a["size"],
                    a["direction"], a["ttl"], a["winsize"], a["flags_byte"],
                    a["proto"], a["s_port"], a["d_port"], a["flow_id"],
                    a["fin"])
            else:
                rt.ingest_packet(
                    int(a["key"][0]), float(a["now"][0]), float(a["rel_ts"][0]),
                    float(a["size"][0]), int(a["direction"][0]),
                    float(a["ttl"][0]), float(a["winsize"][0]),
                    int(a["flags_byte"][0]), float(a["proto"][0]),
                    float(a["s_port"][0]), float(a["d_port"][0]),
                    int(a["flow_id"][0]), bool(a["fin"][0]))
        rt.drain(float(stream.base_t[-1]) + 1.0)
        return rt

    want = run(0)
    got = run(256)
    assert want.metrics.drops_table > 0          # pressure actually happened
    assert want.metrics.summary() == got.metrics.summary()
    wrec, grec = want.dispatcher.records, got.dispatcher.records
    assert len(wrec) == len(grec)
    for w, g in zip(wrec, grec):
        assert (w.bucket, w.n_real, w.reason, w.flush_ts) == \
            (g.bucket, g.n_real, g.reason, g.flush_ts)
        assert (w.flow_ids == g.flow_ids).all()
    _assert_tables_equal(want.table, got.table)


def test_mid_block_flush_recycling_frees_slots_for_later_packets(pipeline):
    """The adversarial case for deferred flush side effects: flows close
    (bidirectional FIN) *before* the full flush that retires them, so
    `mark_predicted` recycles their slots mid-block — and later packets of
    the same block need those slots. Block ingest must admit exactly the
    flows the per-packet cadence admits."""
    depth = DEPTH  # pipeline pkt_depth

    def seq():
        pkts = []  # (key, fid, direction, fin)
        for f in range(4):          # flows A..D: depth pkts, then 2 FINs
            for p in range(depth):
                pkts.append((100 + f, f, p % 2, False))
            if f < 3:               # A,B,C close before the flush fires
                pkts.append((100 + f, f, 0, True))
                pkts.append((100 + f, f, 1, True))
        # D's depth-th packet above made the queue hit max_batch=4 -> full
        # flush; A,B,C had fin_mask==3, so their slots recycle there.
        for f in range(4, 7):       # E,F,G need the freed slots
            pkts.append((200 + f, f, 0, False))
        return pkts

    def run(block: bool):
        rt = StreamingRuntime(pipeline, capacity=4, max_batch=4,
                              min_bucket=4, flush_timeout_s=10.0,
                              execute=False)
        pkts = seq()
        n = len(pkts)
        key = np.array([p[0] for p in pkts], np.uint64)
        t = np.arange(n, dtype=np.float64) * 1e-4
        dirn = np.array([p[2] for p in pkts], np.uint8)
        fin = np.array([p[3] for p in pkts])
        fid = np.array([p[1] for p in pkts], np.int64)
        ones = np.ones(n, np.float32)
        if block:
            rt.ingest_packets(key, t, t.astype(np.float32), ones * 99, dirn,
                              ones * 64, ones * 1000,
                              np.full(n, 0x10, np.uint8), ones * 6, ones,
                              ones * 2, fid, fin)
        else:
            for i in range(n):
                rt.ingest_packet(int(key[i]), float(t[i]), float(t[i]), 99.0,
                                 int(dirn[i]), 64.0, 1000.0, 0x10, 6.0, 1.0,
                                 2.0, int(fid[i]), bool(fin[i]))
        return rt

    want = run(False)
    got = run(True)
    assert want.metrics.drops_table == 0     # scalar cadence admits E,F,G
    assert want.metrics.flows_seen == 7
    assert got.metrics.summary() == want.metrics.summary()
    _assert_tables_equal(want.table, got.table)


def test_chunked_replay_matches_per_packet_reference(pipeline, stream):
    """The production replay (vectorized admission + Lindley recurrence)
    reproduces a straight per-packet reference loop: same drops, same
    batches, same predictions, latency equal to float tolerance."""
    from collections import deque

    svc = ServiceModel.modeled(pipeline.rep, pipeline.forest)
    def mk(execute=True):
        return StreamingRuntime(
            pipeline, capacity=1024, max_batch=64, execute=execute)


    stats = replay(stream, mk, stream.base_pps, svc)

    # reference: the scalar driver (pre-vectorization semantics)
    rt = mk(True)
    m = rt.metrics
    busy_ingest = busy_infer = 0.0
    ring = deque()
    lat = []
    t_e = stream.base_t * 1.0  # offered = base rate -> no compression

    def on_batches(recs):
        nonlocal busy_ingest, busy_infer
        for rec in recs:
            busy_ingest += svc.submit_ns(rec.n_real) * 1e-9
            done = max(rec.flush_ts, busy_infer) + svc.batch_ns(rec.bucket) * 1e-9
            busy_infer = done
            lat.extend(done - rec.ready_ts)

    t = 0.0
    for i in range(stream.n_events):
        t = t_e[i]
        while ring and ring[0] <= t:
            ring.popleft()
        if len(ring) >= 4096:
            m.pkts_total += 1
            m.drops_ring += 1
            continue
        f = int(stream.fid[i])
        a0 = m.pkts_accumulated
        _, recs = rt.ingest_packet(
            int(stream.key[f]), t, float(stream.rel_ts32[i]),
            float(stream.size[i]), int(stream.direction[i]),
            float(stream.ttl[i]), float(stream.winsize[i]),
            int(stream.flags_byte[i]), float(stream.proto[f]),
            float(stream.s_port[f]), float(stream.d_port[f]), f,
            bool(stream.fin[i]))
        busy_ingest = max(t, busy_ingest) + svc.packet_ns(
            m.pkts_accumulated > a0) * 1e-9
        ring.append(busy_ingest)
        on_batches(recs)
        if (i + 1) % 512 == 0:
            on_batches(rt.poll(t))
    on_batches(rt.drain(t + rt.dispatcher.flush_timeout_s))

    assert stats.drops == m.drops
    assert stats.metrics.batches == m.batches
    assert stats.metrics.flows_predicted == m.flows_predicted
    assert stats.predictions == dict(rt.results)
    assert stats.latency_p99_s == pytest.approx(
        float(np.percentile(lat, 99)), rel=1e-9)


def test_replay_fallback_path_on_saturation(pipeline, stream):
    """Above saturation the admission bound fails, the per-packet fallback
    engages, and drops are counted — the bisection's upper bracket."""
    svc = ServiceModel.modeled(pipeline.rep, pipeline.forest)
    def mk(execute=True):
        return StreamingRuntime(
            pipeline, capacity=512, max_batch=64, execute=execute)

    # drive far past the ingest lane's modeled service rate so the ring
    # must overflow regardless of the calibrated constants
    sat_pps = 4e9 / max(svc.pkt_track_ns, 1e-3)
    hot = replay(stream, lambda: mk(False), max(sat_pps, stream.base_pps), svc,
                 ring_capacity=256)
    assert hot.drops > 0
    cool = replay(stream, lambda: mk(False), stream.base_pps, svc,
                  ring_capacity=256)
    assert cool.drops == 0


def test_arena_rotation_protects_pending_batches(pipeline, stream, ds):
    """Donation/zero-copy safety: with double-buffered dispatch the staging
    arenas rotate max_pending+1 deep, so overwriting the next batch cannot
    corrupt an in-flight one — streaming predictions stay bit-identical to
    the batch pipeline."""
    disp = StreamingRuntime(pipeline, capacity=64, max_batch=16).dispatcher
    arenas = [disp.gather(np.arange(4), 16) for _ in range(4)]
    ids = [id(a.ts) for a in arenas]
    assert len(set(ids[:3])) == 3          # max_pending+1 distinct arenas
    assert ids[3] == ids[0]                # then the rotation wraps

    svc = ServiceModel.modeled(pipeline.rep, pipeline.forest)
    stats = replay(
        stream,
        lambda execute=True: StreamingRuntime(
            pipeline, capacity=1024, max_batch=32, max_pending=2,
            execute=execute),
        stream.base_pps, svc)
    assert stats.drops == 0
    batch_preds = pipeline(ds.truncate(DEPTH))
    stream_preds = np.array([stats.predictions[i] for i in range(ds.n_flows)])
    assert (stream_preds == batch_preds).all()

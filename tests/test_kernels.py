"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.forest import forest_apply_np, train_forest
from repro.kernels import ops, ref

R = np.random.default_rng(0)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(R.standard_normal(shape) * scale, dtype)


@pytest.mark.parametrize("B,Hq,Hkv,Tq,Tk,D", [
    (1, 2, 2, 128, 128, 32),
    (2, 4, 2, 256, 256, 64),
    (1, 8, 1, 128, 256, 64),   # strong GQA + cross lengths
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Hq, Hkv, Tq, Tk, D, causal, dtype):
    q = _arr((B, Hq, Tq, D), dtype)
    k = _arr((B, Hkv, Tk, D), dtype)
    v = _arr((B, Hkv, Tk, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("B,Hq,Hkv,S,D,bs", [
    (2, 4, 2, 256, 64, 128),
    (3, 8, 8, 512, 32, 256),   # MHA
    (1, 16, 2, 300, 64, 128),  # padding path
])
def test_decode_attention_sweep(B, Hq, Hkv, S, D, bs):
    q = _arr((B, Hq, D))
    kc = _arr((B, S, Hkv, D))
    vc = _arr((B, S, Hkv, D))
    lens = jnp.asarray(R.integers(1, S + 1, B), jnp.int32)
    out = ops.decode_attention(q, kc, vc, lens, block_s=bs)
    want = ref.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("n,F,K,T,depth", [
    (200, 6, 3, 7, 4),
    (512, 12, 28, 16, 6),
    (100, 4, 2, 3, 5),         # tree padding path (3 % 4 != 0)
])
def test_forest_infer_sweep(n, F, K, T, depth):
    X = R.standard_normal((n, F)).astype(np.float32)
    y = R.integers(0, K, n)
    f = train_forest(X, y, n_trees=T, max_depth=depth,
                     rng=np.random.default_rng(1))
    want = forest_apply_np(f, X)
    got = ops.forest_infer(
        jnp.asarray(X), jnp.asarray(f.feature), jnp.asarray(f.threshold),
        jnp.asarray(f.leaf), f.depth, block_n=128, block_t=4,
    )
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)
    got_ref = ref.forest_infer_ref(
        jnp.asarray(X), jnp.asarray(f.feature), jnp.asarray(f.threshold),
        jnp.asarray(f.leaf), f.depth,
    )
    np.testing.assert_allclose(np.asarray(got_ref), want, atol=1e-5)


@pytest.mark.parametrize("n,F,K,T,depth,block_t", [
    (64, 5, 4, 6, 3, 4),       # tree padding (6 % 4 != 0)
    (130, 9, 2, 8, 5, 8),      # flow padding (130 % 128 != 0)
    (256, 3, 7, 12, 6, 4),
])
def test_forest_infer_ref_vs_kernel_random(n, F, K, T, depth, block_t):
    """Direct ref-vs-Pallas parity on *random* dense forests: arbitrary
    feature ids, thresholds (incl. +inf pass-through slots) and leaves —
    not just trainer-produced trees."""
    rng = np.random.default_rng(n + T)
    n_int, n_leaf = 2 ** depth - 1, 2 ** depth
    feature = rng.integers(0, F, (T, n_int)).astype(np.int32)
    threshold = rng.standard_normal((T, n_int)).astype(np.float32)
    threshold[rng.random((T, n_int)) < 0.15] = np.inf  # pass-through slots
    leaf = rng.random((T, n_leaf, K)).astype(np.float32)
    X = rng.standard_normal((n, F)).astype(np.float32)
    got = ops.forest_infer(
        jnp.asarray(X), jnp.asarray(feature), jnp.asarray(threshold),
        jnp.asarray(leaf), depth, block_n=128, block_t=block_t,
    )
    want = ref.forest_infer_ref(
        jnp.asarray(X), jnp.asarray(feature), jnp.asarray(threshold),
        jnp.asarray(leaf), depth,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("n,P", [(64, 32), (300, 96), (1000, 128)])
def test_flow_stats_sweep(n, P):
    v = _arr((n, P))
    m = jnp.asarray(R.random((n, P)) < 0.4)
    got = ops.flow_stats(v, m, block_n=128)
    want = ref.flow_stats_ref(v, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # empty-mask row
    m0 = jnp.zeros((n, P), bool)
    got0 = ops.flow_stats(v, m0, block_n=128)
    assert np.all(np.asarray(got0) == 0)


@pytest.mark.parametrize("B,T,H,P,S,chunk", [
    (1, 128, 2, 16, 8, 32),
    (2, 256, 4, 32, 16, 64),
    (1, 192, 1, 64, 4, 64),
])
def test_mamba_scan_sweep(B, T, H, P, S, chunk):
    x = _arr((B, T, H, P), scale=0.5)
    dt = jnp.abs(_arr((B, T, H), scale=0.1)) + 0.01
    A = -jnp.abs(_arr((H,), scale=1.0)) - 0.1
    Bm = _arr((B, T, S), scale=0.3)
    Cm = _arr((B, T, S), scale=0.3)
    got = ops.mamba_scan(x, dt, A, Bm, Cm, chunk=chunk)
    want = ref.mamba_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-4)


def test_chunked_ssd_matches_kernel_path():
    """The model-side chunked SSD equals the Pallas kernel recurrence."""
    from repro.models.ssm import chunked_ssd

    B, T, H, P, S = 2, 128, 2, 16, 8
    x = _arr((B, T, H, P), scale=0.5)
    dt = jnp.abs(_arr((B, T, H), scale=0.1)) + 0.01
    A = -jnp.abs(_arr((H,), scale=1.0)) - 0.1
    Bm = _arr((B, T, S), scale=0.3)
    Cm = _arr((B, T, S), scale=0.3)
    y_model, _ = chunked_ssd(x, dt * A, dt, Bm[:, :, None], Cm[:, :, None], chunk=32)
    y_ref = ref.mamba_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_ref), atol=3e-4)

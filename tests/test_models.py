"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import decode_step, forward, init_cache, init_params, loss_fn

ARCHS = list(configs.all_arch_ids())


def _batch(cfg, B=2, T=16, rng=None):
    rng = rng or np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    batch = {"tokens": toks, "targets": toks}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, 8, cfg.d_model)) * 0.1, jnp.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_patches, cfg.d_model)) * 0.1,
            jnp.float32,
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + loss + grad step on a reduced config, CPU: shapes + no NaNs."""
    cfg = configs.get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = forward(params, batch, cfg)
    T_exp = batch["tokens"].shape[1] + (
        cfg.num_patches if cfg.family == "vlm" else 0
    )
    assert logits.shape == (2, T_exp, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_steps(arch):
    cfg = configs.get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, 2, 32)
    toks = jnp.asarray([1, 2], jnp.int32)
    for _ in range(3):
        logits, cache = decode_step(params, cache, toks, cfg)
        assert logits.shape == (2, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
    assert int(cache["pos"][0]) == 3


@pytest.mark.parametrize("arch", ["qwen3-8b", "qwen2-moe-a2.7b", "xlstm-350m",
                                  "zamba2-1.2b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the full forward pass logits."""
    cfg = configs.get_reduced(arch)
    if cfg.family == "moe":
        # capacity drops depend on batch composition; make dropless
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    B, T = 2, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    full = np.asarray(
        forward(params, {"tokens": toks}, cfg).astype(jnp.float32)
    )

    cache = init_cache(cfg, B, T + 1)
    got = []
    for t in range(T):
        logits, cache = decode_step(params, cache, toks[:, t], cfg)
        got.append(np.asarray(logits.astype(jnp.float32)))
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, full, atol=2e-3, rtol=2e-3)


def test_moe_routing_is_sparse():
    """Top-k MoE touches at most k + shared experts per token."""
    from repro.models.moe import router_topk

    cfg = configs.get_reduced("kimi-k2-1t-a32b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((5, cfg.d_model)),
                    jnp.float32)
    w, sel = router_topk(x, params["blocks"]["moe"]["w_router"][0],
                         cfg.experts_per_tok)
    assert sel.shape == (5, cfg.experts_per_tok)
    assert (np.asarray(sel) < cfg.n_experts).all()
    np.testing.assert_allclose(np.asarray(w).sum(-1), 1.0, atol=1e-5)


def test_config_dimensions_match_assignment():
    dims = {
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 0, 163840),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 0, 151936),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }
    for arch, (L, d, H, kv, ff, V) in dims.items():
        cfg = configs.get(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == H, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == V, arch
    # MoE particulars
    k2 = configs.get("kimi-k2-1t-a32b")
    assert (k2.n_experts, k2.experts_per_tok, k2.moe_d_ff) == (384, 8, 2048)
    assert k2.total_params > 0.9e12, "kimi should be ~1T params"
    qm = configs.get("qwen2-moe-a2.7b")
    assert (qm.n_experts, qm.experts_per_tok, qm.n_shared_experts) == (60, 4, 4)
    zb = configs.get("zamba2-1.2b")
    assert zb.ssm_state == 64

"""MoE dispatch invariants (hypothesis property tests on moe_ref)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # seeded-sampling fallback, see tests/_hypothesis_shim.py
    from _hypothesis_shim import given, settings, strategies as st

from repro.models.moe import _capacity, _dispatch_indices, init_moe, moe_ref


@dataclasses.dataclass(frozen=True)
class Cfg:
    n_experts: int
    experts_per_tok: int
    n_shared_experts: int
    moe_d_ff: int
    capacity_factor: float
    n_expert_slots: int = 0

    @property
    def expert_slots(self):
        return self.n_expert_slots or self.n_experts


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(4, 64),
    e=st.integers(2, 12),
    k=st.integers(1, 4),
    seed=st.integers(0, 100),
)
def test_dispatch_indices_bijective_under_capacity(n, e, k, seed):
    """Every kept slot maps to a unique (bucket, position) cell."""
    rng = np.random.default_rng(seed)
    k = min(k, e)
    sel = jnp.asarray(rng.integers(0, e, n * k))
    C = _capacity(n * k, e, 1.25)
    order, sorted_b, pos, keep = _dispatch_indices(sel, e, C)
    order, sorted_b, pos, keep = map(np.asarray, (order, sorted_b, pos, keep))
    cells = {(int(b), int(p)) for b, p in zip(sorted_b[keep], pos[keep])}
    assert len(cells) == keep.sum(), "dispatch cells must be unique"
    assert (pos[keep] < C).all()
    # order is a permutation
    assert sorted(order.tolist()) == list(range(n * k))


def test_dropless_moe_conserves_every_token():
    """With generous capacity, every token receives exactly its k experts'
    weighted outputs — verified against a dense (all-experts) computation."""
    cfg = Cfg(n_experts=6, experts_per_tok=2, n_shared_experts=0,
              moe_d_ff=16, capacity_factor=64.0)
    d = 12
    params = init_moe(jax.random.PRNGKey(0), d, cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, d), jnp.float32) * 0.3

    got = moe_ref(x, params, cfg)

    # dense oracle: run every expert on every token, combine by router weights
    from repro.models.moe import router_topk

    xt = x.reshape(-1, d)
    w, sel = router_topk(xt, params["w_router"], cfg.experts_per_tok)
    dense = []
    for e in range(cfg.n_experts):
        g = xt @ params["w_gate"][e]
        u = xt @ params["w_up"][e]
        h = jax.nn.silu(g) * u
        dense.append(h @ params["w_down"][e])
    dense = jnp.stack(dense, 1)  # (N, E, d)
    want = jnp.zeros_like(xt)
    for j in range(cfg.experts_per_tok):
        want = want + jnp.take_along_axis(
            dense, sel[:, j][:, None, None], axis=1
        )[:, 0] * w[:, j][:, None]
    np.testing.assert_allclose(
        np.asarray(got.reshape(-1, d)), np.asarray(want), atol=2e-5
    )


def test_capacity_drops_are_bounded():
    """With cf=1.0 and adversarial routing, dropped fraction stays < 1."""
    cfg = Cfg(n_experts=4, experts_per_tok=1, n_shared_experts=0,
              moe_d_ff=8, capacity_factor=1.0)
    d = 8
    params = init_moe(jax.random.PRNGKey(0), d, cfg, dtype=jnp.float32)
    x = jnp.ones((1, 32, d), jnp.float32)  # identical tokens -> same expert
    out = moe_ref(x, params, cfg)
    nz = np.count_nonzero(np.abs(np.asarray(out)).sum(-1) > 1e-9)
    # capacity ceil(32/4) = 8 tokens survive on the hot expert
    assert nz == 8

"""Batched multi-fidelity optimization + compile-to-deploy loop
(DESIGN.md §10): sequential-equivalence pin, promotion policy, shared
memoization, and the ParetoBundle artifact."""
import numpy as np
import pytest

from repro.core import (
    CatoOptimizer,
    MemoizedEvaluator,
    Observation,
    SearchSpace,
    build_priors,
    knee_index,
)
from repro.core.acquisition import (
    apply_pibo, ehvi, qehvi_greedy, scalarized_ei,
)
from repro.core.baselines import run_iterate_all
from repro.core.pareto import normalize_objectives, pareto_mask
from repro.core.surrogate import RFSurrogate

NAMES = tuple(f"f{i}" for i in range(6))
VALUE = np.array([0.6, 0.35, 0.15, 0.05, 0.0, 0.0])
COST = np.array([1.0, 6.0, 0.3, 3.0, 10.0, 0.5])


def expensive(x):
    idx = [NAMES.index(f) for f in x.features]
    perf = 1 - np.exp(-VALUE[idx].sum() * (1 + 0.5 * min(x.depth, 6) / 6))
    cost = COST[idx].sum() * (1 + 0.08 * x.depth)
    return cost, perf


def cheap(x):
    # biased-but-correlated proxy: what a cost model is to a measurement
    c, p = expensive(x)
    return 0.9 * c + 0.2, 0.95 * p


@pytest.fixture(scope="module")
def space():
    return SearchSpace(NAMES, max_depth=20)


@pytest.fixture(scope="module")
def toy_priors(space):
    rng = np.random.default_rng(42)
    y = rng.integers(0, 2, 1500)
    X = np.stack(
        [y * VALUE[i] * 3 + rng.normal(0, 1, 1500) for i in range(6)], 1)
    return build_priors(space, X, y)


# ---------------------------------------------------------------------------
# the batched loop at batch_size=1 IS the paper's sequential loop
# ---------------------------------------------------------------------------

def _reference_sequential(space, profiler, priors, n_iterations, seed,
                          n_init=3, candidate_pool=512, pibo_beta=3.0):
    """The pre-batching sequential loop, inlined verbatim: pins the
    refactored optimizer's batch_size=1 path draw-for-draw (same rng
    stream, same acquisition alternation, same argmax)."""
    rng = np.random.default_rng(seed)
    surrogate = RFSurrogate(seed=seed)
    observations, seen = [], set()

    def evaluate(x, it):
        cost, perf = profiler(x)
        o = Observation(x, float(cost), float(perf), iteration=it)
        observations.append(o)
        seen.add(x.key())
        return o

    def candidates(n):
        cands = []
        if priors is not None:
            cands += space.sample_from_priors(
                rng, int(n * 0.6), priors.feature_probs, priors.depth_pmf)
        cands += space.sample_uniform(rng, n - len(cands))
        if observations:
            Y = np.array([o.objectives for o in observations])
            inc = [o.x for o, m in zip(observations, pareto_mask(Y)) if m]
            for x in inc:
                for _ in range(4):
                    cands.append(space.mutate(rng, x))
        fresh, dup = [], set()
        for c in cands:
            k = c.key()
            if k in seen or k in dup:
                continue
            dup.add(k)
            fresh.append(c)
        return fresh

    def propose(iteration):
        cands = candidates(candidate_pool)
        if not cands:
            return space.sample_uniform(rng, 1)[0]
        Y = np.array([o.objectives for o in observations], dtype=np.float64)
        Yn, _, _ = normalize_objectives(Y)
        X_obs = np.stack([space.encode(o.x) for o in observations])
        try:
            surrogate.fit(X_obs, Yn)
        except Exception:
            return cands[int(rng.integers(len(cands)))]
        X_cand = np.stack([space.encode(c) for c in cands])
        post = surrogate.posterior_samples(X_cand)
        front = Yn[pareto_mask(Yn)]
        if iteration % 2 == 0:
            acq = ehvi(post, front)
        else:
            lam = float(rng.beta(0.3, 0.3))
            acq = scalarized_ei(post, Yn, lam)
        if priors is not None:
            pl = getattr(priors, "pi_log_clipped", priors.pi_log)
            lp = np.array([pl(space, c) for c in cands])
            acq = apply_pibo(acq, lp, iteration, pibo_beta)
        return cands[int(np.argmax(acq))]

    n0 = min(n_init, n_iterations)
    init = (
        space.sample_from_priors(
            rng, n0, priors.feature_probs, priors.depth_pmf)
        if priors is not None else space.sample_uniform(rng, n0)
    )
    for i, x in enumerate(init):
        evaluate(x, i)
    for it in range(len(observations), n_iterations):
        evaluate(propose(it), it)
    return observations


@pytest.mark.parametrize("use_priors", [True, False])
def test_batch_size_1_matches_sequential_loop(space, toy_priors, use_priors):
    pri = toy_priors if use_priors else None
    ref = _reference_sequential(space, expensive, pri, 18, seed=3)
    res = CatoOptimizer(space, expensive, pri, seed=3, batch_size=1).run(18)
    got = [(o.x.key(), o.cost, o.perf, o.iteration) for o in res.observations]
    want = [(o.x.key(), o.cost, o.perf, o.iteration) for o in ref]
    assert got == want, "batched loop at q=1 drifted from the sequential loop"


def test_qehvi_greedy_first_pick_is_ehvi_argmax_and_batch_distinct():
    rng = np.random.default_rng(7)
    post = rng.random((16, 40, 2))
    front = np.array([[0.2, 0.8], [0.5, 0.4], [0.9, 0.1]])
    idx = qehvi_greedy(post, front, 5)
    assert len(idx) == len(set(idx)) == 5
    assert idx[0] == int(np.argmax(ehvi(post, front)))
    # fantasizing the pick must not *raise* later scores: greedy HVI
    # contributions are non-increasing along the batch
    contribs = []
    fronts = [front] * post.shape[0]
    from repro.core.acquisition import hvi_contribution
    for pick in idx:
        acc = np.mean([hvi_contribution(f, p)[pick]
                       for f, p in zip(fronts, post)])
        contribs.append(acc)
        fronts = [np.vstack([f, p[pick][None]])
                  for f, p in zip(fronts, post)]
    assert all(a >= b - 1e-12 for a, b in zip(contribs, contribs[1:]))


# ---------------------------------------------------------------------------
# multi-fidelity loop invariants
# ---------------------------------------------------------------------------

def test_multi_fidelity_reports_measured_front_only(space, toy_priors):
    ev = MemoizedEvaluator({"modeled": cheap, "measured": expensive})
    opt = CatoOptimizer(space, ev, toy_priors, seed=0, batch_size=4)
    res = opt.run_multi_fidelity(measure_budget=6)
    assert res.measured_fidelity == "measured"
    assert res.fidelity_counts["measured"] <= 6
    assert res.fidelity_counts["modeled"] >= opt.n_init
    front = res.pareto_observations()
    assert front and all(o.fidelity == "measured" for o in front)
    # the front really is non-dominated within the measured set
    Ym = np.array([o.objectives for o in res.observations_at("measured")])
    assert len(front) == int(pareto_mask(Ym).sum())


def test_promotion_never_measures_a_dominated_candidate(space, toy_priors):
    ev = MemoizedEvaluator({"modeled": cheap, "measured": expensive})
    opt = CatoOptimizer(space, ev, toy_priors, seed=1, batch_size=4)
    res = opt.run_multi_fidelity(measure_budget=8)
    assert res.fidelity_counts.get("measured"), "nothing was ever promoted"
    for i, o in enumerate(res.observations):
        if o.fidelity != "measured":
            continue
        prior_cheap = [p for p in res.observations[:i]
                       if p.fidelity == "modeled"]
        mine = [p for p in prior_cheap if p.x.key() == o.x.key()]
        assert mine, "promoted a config never evaluated at the cheap fidelity"
        y = np.array(mine[0].objectives)
        for p in prior_cheap:
            yp = np.array(p.objectives)
            assert not (np.all(yp <= y) and np.any(yp < y)), (
                f"promoted {o.x} although {p.x} dominated it at the cheap "
                "fidelity"
            )


def test_measured_budget_is_never_spent_on_memo_hits():
    # a 2-feature space is tiny enough that prior/uniform sampling keeps
    # re-proposing the same configs: every measured observation must
    # still be a distinct config backed by a real backend call
    tiny = SearchSpace(("a", "b"), max_depth=2)

    def t_exp(x):
        return len(x.features) + 0.1 * x.depth, float(len(x.features))

    def t_cheap(x):
        c, p = t_exp(x)
        return 0.9 * c, 0.9 * p

    ev = MemoizedEvaluator({"modeled": t_cheap, "measured": t_exp})
    opt = CatoOptimizer(tiny, ev, seed=0, n_init=6, batch_size=3)
    res = opt.run_multi_fidelity(measure_budget=4, max_rounds=30)
    measured = res.observations_at("measured")
    keys = [o.x.key() for o in measured]
    assert len(keys) == len(set(keys)), "a config was measured twice"
    assert ev.n_calls["measured"] == len(measured), (
        "budget slots were burned on memoized repeats")
    # cheap init was deduped too
    cheap_keys = [o.x.key() for o in res.observations_at("modeled")]
    assert len(cheap_keys) == len(set(cheap_keys))


def test_multi_fidelity_requires_a_fidelity_spectrum(space):
    opt = CatoOptimizer(space, expensive, seed=0)
    with pytest.raises(ValueError, match="multi-fidelity"):
        opt.run_multi_fidelity(measure_budget=2)


def test_surrogate_fallbacks_are_counted(space):
    class Brittle(RFSurrogate):
        def fit(self, X, Y):
            raise RuntimeError("boom")

    opt = CatoOptimizer(space, expensive, seed=0, surrogate=Brittle())
    with pytest.warns(RuntimeWarning, match="surrogate fit failed"):
        res = opt.run(8)
    # every post-init iteration degraded to random, and the result says so
    assert res.surrogate_fallbacks == list(range(3, 8))
    assert len(res.observations) == 8


# ---------------------------------------------------------------------------
# shared memoization across algorithms (real profiler, bit-identical)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mini_profiler():
    from repro.traffic import MINI_FEATURE_NAMES, TrafficProfiler, make_dataset

    ds = make_dataset("iot-class", n_flows=300, max_pkts=12, seed=0)
    return TrafficProfiler(ds, MINI_FEATURE_NAMES, model="tree-fast",
                           cost_metric="exec_time", cost_mode="modeled",
                           seed=0)


def test_memoization_is_bit_identical_across_algorithms(mini_profiler):
    from repro.traffic import MINI_FEATURE_NAMES

    space = SearchSpace(MINI_FEATURE_NAMES, max_depth=12)
    ev = MemoizedEvaluator(mini_profiler)
    # two "algorithms" requesting the same configs: ITERATEALL twice
    res_a = run_iterate_all(space, ev, 6)
    res_b = run_iterate_all(space, ev, 6)
    for oa, ob in zip(res_a.observations, res_b.observations):
        assert oa.x.key() == ob.x.key()
        assert oa.cost == ob.cost and oa.perf == ob.perf
    # the cached ProfileResult object itself is returned, not a re-run
    x = res_a.observations[0].x
    r1, _ = ev.profile(x)
    r2, _ = ev.profile(x)
    assert r1 is r2
    fid = ev.measured
    assert ev.n_calls[fid] == 6
    assert ev.n_hits[fid] >= 7  # 6 from the repeat run + 2 probes - 1


def test_backend_suite_ordering_and_metrics(mini_profiler):
    from repro.traffic import backend_suite

    suite = backend_suite(mini_profiler, ("modeled", "replayed"))
    assert list(suite) == ["modeled", "replayed"]
    assert suite["modeled"].metric == "throughput"
    assert suite["replayed"].metric == "throughput_replayed"
    with pytest.raises(ValueError, match="cheap -> expensive"):
        backend_suite(mini_profiler, ("replayed", "modeled"))
    with pytest.raises(ValueError, match="unknown fidelities"):
        backend_suite(mini_profiler, ("modeled", "live_nic"))


def test_perf_cache_returns_the_same_forest(mini_profiler):
    from repro.core import FeatureRep

    x = FeatureRep(mini_profiler.feature_names[:3], 6)
    f1_a, forest_a = mini_profiler.perf_f1(x)
    f1_b, forest_b = mini_profiler.perf_f1(x)
    assert f1_a == f1_b
    assert forest_a is forest_b  # deploy gets the measured model, bit-exact


# ---------------------------------------------------------------------------
# ParetoBundle: serialize -> load -> deploy
# ---------------------------------------------------------------------------

def test_pareto_bundle_roundtrip(tmp_path, mini_profiler):
    from repro.serve.deploy import ParetoBundle, compile_front
    from repro.traffic import MINI_FEATURE_NAMES

    space = SearchSpace(MINI_FEATURE_NAMES, max_depth=12)
    res = CatoOptimizer(space, MemoizedEvaluator(mini_profiler), seed=0).run(8)
    bundle = compile_front(res, mini_profiler, fused=False, warm=False)
    assert bundle.points == sorted(bundle.points, key=lambda p: p.cost)
    assert bundle.meta["measured_fidelity"] is None  # single-fidelity run

    path = bundle.save(tmp_path / "bundle.json")
    again = ParetoBundle.load(path)
    assert again.to_doc() == bundle.to_doc()
    # the model payload reconstructs bit-exactly
    for p0, p1 in zip(bundle.points, again.points):
        f0, f1 = p0.forest(), p1.forest()
        assert np.array_equal(f0.feature, f1.feature)
        assert np.array_equal(f0.threshold, f1.threshold)
        assert np.array_equal(f0.leaf, f1.leaf)
        assert f0.depth == f1.depth and f0.n_features == f1.n_features
    # selection is stable across the round-trip
    assert again.knee().rep == bundle.knee().rep
    assert again.best_by_perf().rep == bundle.best_by_perf().rep
    # a deserialized point compiles into a servable pipeline
    pipe = again.knee().build(warm=False)
    pipe.warm([8])  # one tiny bucket: exercises the real jit entry


def test_compile_front_max_points_keeps_extremes_and_knee(mini_profiler):
    from repro.core import CatoResult, FeatureRep
    from repro.serve.deploy import compile_front
    from repro.traffic import MINI_FEATURE_NAMES

    space = SearchSpace(MINI_FEATURE_NAMES, max_depth=12)
    # a 10-point mutually non-dominated front (cost and perf both rise)
    obs = [
        Observation(FeatureRep(MINI_FEATURE_NAMES[:2], d), float(d),
                    0.1 * d, iteration=d)
        for d in range(1, 11)
    ]
    res = CatoResult(obs, space)
    bundle = compile_front(res, mini_profiler, fused=False, warm=False,
                           max_points=3)
    kept = {p.rep for p in bundle.points}
    front = res.pareto_observations()
    assert len(bundle.points) == 3
    assert front[0].x in kept, "low-cost extreme dropped"
    assert front[-1].x in kept, "high-cost extreme dropped"
    assert bundle.best_by_perf().rep == front[-1].x
    assert bundle.best_by_cost().rep == front[0].x


def test_knee_index_picks_the_elbow():
    front = np.array([
        [0.0, 1.00],
        [0.1, 0.30],   # the elbow: big perf gain, small cost
        [0.5, 0.25],
        [1.0, 0.20],
    ])
    assert knee_index(front) == 1
    assert knee_index(front[:1]) == 0
    with pytest.raises(ValueError):
        knee_index(np.zeros((0, 2)))

"""Multi-tenant white-box serving (DESIGN.md §15).

The contracts under test:

- plan merging: shared (op, depth) work units are deduped across tenants,
  and every tenant's static column map reads back exactly its own plan;
- column-subset property: over random tenant rep sets, each tenant's
  columns of the merged extraction matrix match its solo extraction at
  its own connection depth to float32 ulp (the depth-group static
  slicing that makes sharing an optimization, not a model change);
- fused ≡ unfused ≡ solo: the single multi-forest kernel launch, the
  unfused gather path, and N solo pipelines agree bitwise, lane by lane;
- serving parity: a shared fleet under overflow pressure and control-plane
  migration produces per-tenant predictions bit-identical to N solo
  fleets replaying the same stream, and attributes per-tenant counters;
- deploy: `MultiTenantBundlePoint` round-trips through its document form,
  `compile_multi_tenant` fuses per-tenant points (cost = independent sum,
  the discount is what deployment buys), and a fused bundle hot-swaps
  into a live fleet with zero drops and exactly-once prediction;
- co-optimization: `MultiTenantProfiler` prices the union plan below the
  independent sum for overlapping tenants, identically for perf;
- observability: per-tenant prediction counters survive the registry
  round-trip, render as ``tenant`` labels in valid Prometheus output,
  and the replay tracer carries per-tenant infer sub-lanes.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.search_space import FeatureRep
from repro.serve import (
    PacketStream,
    ServeSession,
    ServiceModel,
    ShardedRuntime,
    build_multi_tenant_pipeline,
    compile_multi_tenant,
    make_swap,
    replay,
)
from repro.serve.control import ControlConfig
from repro.serve.deploy import BundlePoint, MultiTenantBundlePoint, _forest_to_doc
from repro.serve.obs import Observability, Tracer, check_prometheus, render_prometheus
from repro.serve.obs.trace import TID_TENANT0
from repro.serve.runtime import RuntimeMetrics
from repro.traffic import TrafficProfiler, extract_features
from repro.traffic.extraction import merge_stats_plans, stats_plan
from repro.traffic.models import train_traffic_model
from repro.traffic.multi_tenant import (
    MultiTenantProfiler,
    MultiTenantRep,
    MultiTenantSpace,
    union_rep,
)
from repro.traffic.pipeline import build_pipeline
from repro.traffic.synth import make_scenario_dataset

FEATURE_POOL = (
    "s_bytes_mean", "s_bytes_max", "s_iat_mean", "d_iat_std", "s_load",
    "d_load", "dur", "proto", "s_port", "s_ttl_mean", "d_pkt_cnt",
    "ack_cnt", "psh_cnt",
)

TENANT_REPS = (
    FeatureRep(("s_bytes_mean", "s_iat_mean", "proto", "s_load"), depth=8),
    FeatureRep(("s_bytes_mean", "s_bytes_max", "dur", "d_load"), depth=12),
    FeatureRep(("s_iat_mean", "s_load", "d_pkt_cnt", "ack_cnt"), depth=8),
)


def _clip(ds, depth):
    """The (rows, depth) view a solo tenant's flow table would hold."""
    d = min(int(depth), ds.max_pkts)
    return dataclasses.replace(
        ds, ts=ds.ts[:, :d], size=ds.size[:, :d],
        direction=ds.direction[:, :d], ttl=ds.ttl[:, :d],
        winsize=ds.winsize[:, :d], flags=ds.flags[:, :d, :])


@pytest.fixture(scope="module")
def ds():
    return make_scenario_dataset("app-class", "zipf", n_flows=100,
                                 max_pkts=48, seed=5)


@pytest.fixture(scope="module")
def forests(ds):
    out = []
    for t, rep in enumerate(TENANT_REPS):
        X = extract_features(ds, rep.features, rep.depth)
        out.append(train_traffic_model(X, ds.label, model="tree-fast",
                                       seed=t)[0])
    return tuple(out)


@pytest.fixture(scope="module")
def solo_pipes(ds, forests):
    return [build_pipeline(r, f, max_pkts=r.depth, use_kernel=False)
            for r, f in zip(TENANT_REPS, forests)]


@pytest.fixture(scope="module")
def mt_pipe(forests):
    return build_multi_tenant_pipeline(TENANT_REPS, forests,
                                       use_kernel=False)


@pytest.fixture(scope="module")
def stream(ds):
    return PacketStream.from_dataset(ds, seed=0)


@pytest.fixture(scope="module")
def service():
    return ServiceModel(
        pkt_accum_ns=800.0, pkt_track_ns=200.0,
        bucket_ns={8: 3e4, 16: 4e4, 32: 6e4, 64: 1e5},
        gather_ns_per_flow=200.0, source="synthetic",
    )


# ---------------------------------------------------------------------------
# plan merging
# ---------------------------------------------------------------------------


def test_merge_dedups_shared_work_units():
    plans = [stats_plan(r.features) for r in TENANT_REPS]
    merged, cols = merge_stats_plans(plans, [r.depth for r in TENANT_REPS])
    # dedup is real: strictly fewer merged columns than plan positions
    assert len(merged) < sum(len(p) for p in plans)
    assert len(set(merged)) == len(merged)
    # every tenant's column map reads back exactly its own plan entries
    for plan, c, r in zip(plans, cols, TENANT_REPS):
        assert len(c) == len(plan)
        for pos, mc in enumerate(c):
            entry, depth = merged[mc]
            assert entry == plan[pos]
            assert depth == (0 if entry[0] == "meta" else r.depth)
    # meta entries are depth-0, so they dedup across different depths:
    # tenant0 (depth 8) and tenant2 (depth 6) share `s_load`'s meta deps?
    # directly: same meta feature at two depths -> one merged column
    m2, c2 = merge_stats_plans(
        [stats_plan(("proto",)), stats_plan(("proto",))], [4, 16])
    assert len(m2) == 1 and c2 == ((0,), (0,))


def test_union_rep_is_union_at_max_depth():
    u = union_rep(TENANT_REPS)
    assert u.depth == max(r.depth for r in TENANT_REPS)
    assert set(u.features) == set().union(*(r.features for r in TENANT_REPS))


def test_union_columns_match_solo_extraction_property(ds):
    """Random tenant sets: merged matrix column subsets == solo extracts."""
    import jax.numpy as jnp

    from repro.traffic.extraction import emit_merged_columns

    rng = np.random.default_rng(7)
    for _ in range(5):
        reps = []
        for _t in range(int(rng.integers(2, 5))):
            k = int(rng.integers(2, 6))
            feats = tuple(rng.choice(FEATURE_POOL, size=k, replace=False))
            reps.append(FeatureRep(feats, int(rng.integers(2, 33))))
        plans = [stats_plan(r.features) for r in reps]
        merged, cols = merge_stats_plans(plans, [r.depth for r in reps])
        u = _clip(ds, union_rep(reps).depth)
        out = emit_merged_columns(
            merged, ts=jnp.asarray(u.ts), size=jnp.asarray(u.size),
            direction=jnp.asarray(u.direction), ttl=jnp.asarray(u.ttl),
            winsize=jnp.asarray(u.winsize),
            flags=jnp.asarray(u.flags, jnp.float32),
            flow_len=jnp.asarray(u.flow_len), proto=jnp.asarray(u.proto),
            s_port=jnp.asarray(u.s_port), d_port=jnp.asarray(u.d_port))
        X = np.stack([np.asarray(c) for c in out], axis=1)
        for r, c in zip(reps, cols):
            solo = extract_features(_clip(ds, r.depth), r.features, r.depth)
            # ulp-level: each depth group reduces exactly solo-width
            # slices, but the merged program fuses differently under XLA
            # so float reduction order may differ by one rounding step.
            # End-to-end *predictions* are bit-identical (tests below).
            np.testing.assert_allclose(
                X[:, list(c)], solo, rtol=2e-7, atol=1e-7,
                err_msg=f"tenant {r.features}@{r.depth} columns diverged")


# ---------------------------------------------------------------------------
# fused ≡ unfused ≡ solo
# ---------------------------------------------------------------------------


def test_fused_unfused_solo_bitwise_parity(ds, forests, solo_pipes, mt_pipe):
    fused = build_multi_tenant_pipeline(TENANT_REPS, forests, fused=True)
    batch = _clip(ds, mt_pipe.rep.depth)
    p_unfused = mt_pipe.probabilities(batch)
    p_fused = fused.probabilities(batch)
    np.testing.assert_array_equal(p_fused, p_unfused)
    for t, ((lo, hi), solo, rep) in enumerate(
            zip(mt_pipe.lanes, solo_pipes, TENANT_REPS)):
        solo_p = np.asarray(solo.predict_async(_clip(ds, rep.depth)))
        np.testing.assert_array_equal(
            p_unfused[:, lo:hi], solo_p,
            err_msg=f"tenant {t} probability lane diverged")
    # finalize: column t is tenant t's solo class decisions
    out = mt_pipe.finalize(p_unfused)
    assert out.shape == (ds.n_flows, len(TENANT_REPS))
    for t, (solo, rep) in enumerate(zip(solo_pipes, TENANT_REPS)):
        solo_cls = solo.finalize(solo.predict_async(_clip(ds, rep.depth)))
        np.testing.assert_array_equal(out[:, t], solo_cls)


def test_incremental_entry_matches_merged_plan(mt_pipe):
    # this tenant set is all-incremental (no medians): the aggregate
    # entry must exist so the reuse/refresh path can serve it
    assert mt_pipe.supports_agg
    assert mt_pipe.drift_prob_slice == slice(*mt_pipe.lanes[0])


# ---------------------------------------------------------------------------
# serving parity under pressure + per-tenant observability
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def parity_replays(stream, solo_pipes, mt_pipe, service):
    # capacity 64 << 100 flows forces table overflow/eviction; the
    # control plane migrates flows between the 2 shards mid-trace
    def mk(pipe):
        def fleet():
            return ShardedRuntime(pipe, n_shards=2, capacity=64,
                                  max_batch=32, flush_timeout_s=2e-4,
                                  execute=True)
        return fleet

    cfg = dict(interval_pkts=256)
    sh = replay(stream, mk(mt_pipe), stream.base_pps, service,
                ring_capacity=512,
                session=ServeSession(control=ControlConfig(**cfg)))
    solos = [replay(stream, mk(p), stream.base_pps, service,
                    ring_capacity=512,
                    session=ServeSession(control=ControlConfig(**cfg)))
             for p in solo_pipes]
    return sh, solos


def test_shared_fleet_bitwise_parity_with_solo(parity_replays):
    sh, solos = parity_replays
    assert len(sh.predictions) > 0
    for t, solo in enumerate(solos):
        assert sorted(sh.predictions) == sorted(solo.predictions)
        keys = sorted(sh.predictions)
        np.testing.assert_array_equal(
            np.asarray([sh.predictions[k][t] for k in keys]),
            np.asarray([solo.predictions[k] for k in keys]),
            err_msg=f"tenant {t} diverged from solo fleet")


def test_tenant_prediction_counters(parity_replays):
    sh, _ = parity_replays
    m = sh.metrics
    n = m.flows_predicted
    assert n > 0
    # one fused batch answers every tenant: each lane advances in step
    assert m.tenant_predictions == {t: n for t in range(len(TENANT_REPS))}
    # registry round-trip preserves the per-tenant attribution exactly
    m2 = RuntimeMetrics.from_registry(m.to_registry())
    assert m2.tenant_predictions == m.tenant_predictions
    assert m2.flows_predicted == n
    assert "tenant_predictions" in m.summary()


def test_prometheus_tenant_labels(parity_replays):
    sh, _ = parity_replays
    reg = sh.metrics.to_registry(prefix="shard0.")
    text = render_prometheus(reg)
    assert check_prometheus(text) == []
    want = (f'cato_dispatch_flows_predicted{{shard="0",tenant="1"}} '
            f'{sh.metrics.flows_predicted}')
    assert want in text


# ---------------------------------------------------------------------------
# deploy: bundle round-trip + hot swap
# ---------------------------------------------------------------------------


def _points(forests, reps=TENANT_REPS):
    return [BundlePoint(rep=r, cost=float(1 + t), perf=0.5 + 0.1 * t,
                        fidelity="modeled", aux={},
                        compile_meta={"fused": False, "use_kernel": False},
                        forest_doc=_forest_to_doc(f))
            for t, (r, f) in enumerate(zip(reps, forests))]


def test_bundle_point_roundtrip(ds, forests, mt_pipe):
    mt = compile_multi_tenant(_points(forests), fused=False,
                              use_kernel=False, warm=False)
    assert mt.rep == union_rep(TENANT_REPS)
    assert mt.cost == pytest.approx(sum(1 + t for t in range(3)))
    assert mt.perf == pytest.approx(np.mean([0.5, 0.6, 0.7]))
    assert mt.aux["tenant_costs"] == [1.0, 2.0, 3.0]
    back = MultiTenantBundlePoint.from_doc(mt.to_doc())
    assert back.to_doc() == mt.to_doc()
    assert back.tenant_reps == TENANT_REPS
    # the rebuilt pipeline serves the exact same model
    pipe = back.build(warm=False)
    batch = _clip(ds, mt_pipe.rep.depth)
    np.testing.assert_array_equal(pipe.probabilities(batch),
                                  mt_pipe.probabilities(batch))


def test_hot_swap_multi_tenant_bundle(ds, stream, forests, service):
    reps_b = (
        FeatureRep(("s_bytes_mean", "s_iat_mean", "proto"), depth=6),
        FeatureRep(("s_bytes_mean", "dur", "d_load"), depth=8),
        FeatureRep(("s_load", "d_pkt_cnt"), depth=6),
    )
    forests_b = tuple(
        train_traffic_model(extract_features(ds, r.features, r.depth),
                            ds.label, model="tree-fast", seed=10 + t)[0]
        for t, r in enumerate(reps_b))
    start = compile_multi_tenant(_points(forests), fused=False,
                                 use_kernel=False, warm=False)
    target = compile_multi_tenant(_points(forests_b, reps_b), fused=False,
                                  use_kernel=False, warm=False)

    def fleet():
        return ShardedRuntime(start.pipeline, n_shards=2, capacity=2048,
                              max_batch=32, execute=True)

    swap = make_swap(target, after_pkts=stream.n_events // 2,
                     runtime=fleet())
    stats = replay(stream, fleet, stream.base_pps, service,
                   ring_capacity=1024,
                   session=ServeSession(control=ControlConfig(
                       interval_pkts=256, rebalance=False, swap=swap)))
    assert stats.drops == 0
    assert stats.control["swaps"] == 1
    assert len(stats.predictions) == ds.n_flows
    assert stats.metrics.duplicate_predictions == 0
    # every flow answered once FOR ALL TENANTS, before and after the swap
    assert {np.asarray(v).shape for v in stats.predictions.values()} \
        == {(len(TENANT_REPS),)}


def test_make_swap_uses_multi_tenant_service(forests):
    mt = compile_multi_tenant(_points(forests), fused=False,
                              use_kernel=False, warm=False)
    swap = make_swap(mt, after_pkts=10)
    fr = swap.service.tenant_fracs
    assert fr is not None and len(fr) == len(TENANT_REPS)
    assert sum(fr) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# co-optimization: the profiler prices the sharing
# ---------------------------------------------------------------------------


def test_profiler_overlap_discount(ds):
    pools = (("s_bytes_mean", "s_iat_mean", "s_load", "proto"),
             ("s_bytes_mean", "s_iat_mean", "dur", "ack_cnt"))
    profs = [TrafficProfiler(ds, p, model="tree-fast", cost_mode="modeled",
                             seed=0) for p in pools]
    shared = MultiTenantProfiler(profs, shared=True)
    indep = MultiTenantProfiler(profs, shared=False)
    x = MultiTenantRep((
        FeatureRep(("s_bytes_mean", "s_iat_mean", "s_load"), depth=8),
        FeatureRep(("s_bytes_mean", "s_iat_mean", "dur"), depth=8),
    ))
    r_sh, r_in = shared(x), indep(x)
    # same tenants, same models: perf identical; only the billing moves
    assert r_sh.perf == r_in.perf
    assert r_sh.cost < r_in.cost
    assert r_sh.cost == pytest.approx(r_sh.aux["cost_shared_us"])
    assert r_in.cost == pytest.approx(r_in.aux["cost_independent_us"])
    assert r_sh.aux["overlap_discount"] > 0.1
    # identical tenant plans are the sharing limit: discount grows past
    # the partial-overlap config; disjoint plans share only the window
    # accumulation, so their discount sits strictly below both
    dup = MultiTenantRep((
        FeatureRep(("s_bytes_mean", "s_iat_mean"), depth=8),
        FeatureRep(("s_bytes_mean", "s_iat_mean"), depth=8),
    ))
    disj = MultiTenantRep((
        FeatureRep(("s_bytes_mean",), depth=8),
        FeatureRep(("dur",), depth=8),
    ))
    d_partial = r_sh.aux["overlap_discount"]
    assert shared(dup).aux["overlap_discount"] > d_partial
    assert shared(disj).aux["overlap_discount"] < d_partial


def test_space_protocol_roundtrip():
    spaces = (
        __import__("repro.core.search_space", fromlist=["SearchSpace"])
        .SearchSpace(("s_bytes_mean", "dur", "proto"), max_depth=8),
        __import__("repro.core.search_space", fromlist=["SearchSpace"])
        .SearchSpace(("s_iat_mean", "s_load"), max_depth=4),
    )
    joint = MultiTenantSpace(spaces)
    assert joint.dim == sum(s.dim for s in spaces)
    rng = np.random.default_rng(0)
    xs = joint.sample_uniform(rng, 8)
    for x in xs:
        assert joint.decode(joint.encode(x)) == x
        y = joint.mutate(rng, x)
        # one tenant moved, the others are untouched
        assert sum(a != b for a, b in zip(x.reps, y.reps)) <= 1
    assert joint.encode_batch(xs).shape == (8, joint.dim)


# ---------------------------------------------------------------------------
# replay tracer: per-tenant infer sub-lanes
# ---------------------------------------------------------------------------


def test_trace_has_per_tenant_infer_lanes(stream, mt_pipe, forests):
    svc = ServiceModel.modeled_multi_tenant(TENANT_REPS, forests)
    assert len(svc.tenant_fracs) == len(TENANT_REPS)
    assert sum(svc.tenant_fracs) == pytest.approx(1.0)
    obs = Observability(tracer=Tracer(capacity=1 << 14))
    replay(stream, lambda: ShardedRuntime(mt_pipe, n_shards=2,
                                          capacity=2048, max_batch=32),
           stream.base_pps, svc, session=ServeSession(obs=obs))
    names = set(obs.tracer._names)
    for t in range(len(TENANT_REPS)):
        assert f"infer.tenant{t}" in names
    meta = [e for e in obs.tracer.chrome()["traceEvents"]
            if e.get("name") == "thread_name"
            and e.get("tid", 0) >= TID_TENANT0]
    assert {e["args"]["name"] for e in meta} \
        == {f"tenant {t} infer" for t in range(len(TENANT_REPS))}

"""Unified serving observability (DESIGN.md §11): registry exactness and
order-independent merge, bounded ring tracing with well-formed lifecycle
spans, control-plane audit coverage, and online drift signals that move
under the drift scenario and stay flat under uniform."""
import json

import numpy as np
import pytest

from repro.core.search_space import FeatureRep
from repro.serve import ServeSession
from repro.serve.control import ControlConfig, PipelineSwap
from repro.serve.control.replay import controlled_replay
from repro.serve.obs import (
    AuditLog,
    DriftMonitor,
    MetricsRegistry,
    Observability,
    StreamingMoments,
    Tracer,
    fleet_registry,
)
from repro.serve.runtime import (
    LatencyHistogram,
    PacketStream,
    RuntimeMetrics,
    ServiceModel,
    ShardedRuntime,
    StreamingRuntime,
    replay,
)
from repro.traffic import extract_features
from repro.traffic.models import train_traffic_model
from repro.traffic.pipeline import build_pipeline
from repro.traffic.synth import make_scenario_dataset


@pytest.fixture(scope="module")
def ds():
    # strong elephant skew: static 4-shard imbalance high enough that the
    # control plane rebalances several times within the trace
    return make_scenario_dataset("app-class", "zipf", n_flows=120,
                                 max_pkts=256, seed=3)


def _pipe(ds, rep):
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="tree-fast", seed=0)
    return build_pipeline(rep, forest, max_pkts=rep.depth, use_kernel=False)


@pytest.fixture(scope="module")
def pipeline(ds):
    return _pipe(ds, FeatureRep(
        ("dur", "s_load", "s_bytes_mean", "s_iat_mean", "ack_cnt"), depth=8))


@pytest.fixture(scope="module")
def pipeline_b(ds):
    return _pipe(ds, FeatureRep(
        ("dur", "s_load", "s_pkt_cnt", "d_bytes_med", "psh_cnt"), depth=12))


@pytest.fixture(scope="module")
def stream(ds):
    return PacketStream.from_dataset(ds, seed=0)


@pytest.fixture(scope="module")
def service():
    return ServiceModel(
        pkt_accum_ns=800.0, pkt_track_ns=200.0,
        bucket_ns={8: 3e4, 16: 4e4, 32: 6e4, 64: 1e5},
        gather_ns_per_flow=200.0, source="synthetic",
    )


def fleet(pipeline, n_shards=4, execute=False, **kw):
    return ShardedRuntime(pipeline, n_shards=n_shards, capacity=2048,
                          max_batch=64, execute=execute, **kw)


# ---------------------------------------------------------------------------
# registry: snapshot / delta exactness
# ---------------------------------------------------------------------------


def test_registry_snapshot_delta_exact():
    reg = MetricsRegistry()
    reg.inc("flow_table.evictions", 3)
    reg.set_gauge("flow_table.load_factor", 0.25, reduce="max")
    reg.union("dispatch.shapes_seen", [(8, 5), (16, 5)])
    reg.extend_samples("dispatch.batch_occupancy", [4, 7])
    h = LatencyHistogram()
    h.record_many(np.array([1e-3, 2e-3, 5e-3]))
    reg.attach_hist("dispatch.latency", h)

    s1 = reg.snapshot()
    # untouched registry: two snapshots equal, self-delta all zero
    assert reg.snapshot() == s1
    d0 = MetricsRegistry.delta(s1, s1)
    assert d0["counters"]["flow_table.evictions"] == 0
    assert d0["hists"]["dispatch.latency"]["n"] == 0
    assert not any(d0["hists"]["dispatch.latency"]["counts"])
    assert d0["sets"]["dispatch.shapes_seen"] == []
    assert d0["samples"]["dispatch.batch_occupancy"] == []

    # interval activity, then the delta must be exactly that activity
    reg.inc("flow_table.evictions", 2)
    reg.union("dispatch.shapes_seen", [(32, 5)])
    reg.extend_samples("dispatch.batch_occupancy", [9])
    h.record_many(np.array([3e-3]))
    d = MetricsRegistry.delta(reg.snapshot(), s1)
    assert d["counters"]["flow_table.evictions"] == 2
    assert d["hists"]["dispatch.latency"]["n"] == 1
    assert sum(d["hists"]["dispatch.latency"]["counts"]) == 1
    assert d["sets"]["dispatch.shapes_seen"] == [[32, 5]]
    assert d["samples"]["dispatch.batch_occupancy"] == [9]

    # snapshots are JSON-serializable as-is (the artifact contract)
    json.dumps(reg.snapshot())


def test_registry_snapshot_excludes_reservoir():
    h = LatencyHistogram(max_samples=4)
    h.record_many(np.linspace(1e-3, 9e-3, 50))
    reg = MetricsRegistry()
    reg.attach_hist("dispatch.latency", h)
    doc = reg.snapshot()["hists"]["dispatch.latency"]
    # counts + exact scalars only: the (order-sensitive) reservoir never
    # leaks into a snapshot, so snapshot equality is well-defined
    assert set(doc) == {"n", "counts", "min_s", "max_s", "sum_s"}
    assert doc["n"] == 50
    assert sum(doc["counts"]) == 50
    assert doc["sum_s"] == pytest.approx(float(np.linspace(1e-3, 9e-3, 50).sum()))


def test_runtime_metrics_registry_roundtrip():
    m = RuntimeMetrics()
    for i, f in enumerate(RuntimeMetrics.counter_fields(), start=1):
        setattr(m, f, 10 * i + 3)
    m.batch_occupancy = [1, 5, 9]
    m.shapes_seen = {(8, 4), (16, 4)}
    m.latency.record_many(np.array([2e-3, 4e-3]))
    back = RuntimeMetrics.from_registry(m.to_registry())
    for f in RuntimeMetrics.counter_fields():
        assert getattr(back, f) == getattr(m, f)
    assert back.batch_occupancy == m.batch_occupancy
    assert back.shapes_seen == m.shapes_seen
    assert back.latency.n == m.latency.n


# ---------------------------------------------------------------------------
# registry: cross-shard merge
# ---------------------------------------------------------------------------


def _random_part(seed):
    rng = np.random.default_rng(seed)
    reg = MetricsRegistry()
    reg.inc("ingest.pkts_total", int(rng.integers(1, 1000)))
    reg.inc("flow_table.drops", int(rng.integers(0, 50)))
    reg.set_gauge("flow_table.load_factor", float(rng.random()), reduce="max")
    reg.set_gauge("dispatch.queue_depth", float(rng.integers(0, 9)),
                  reduce="sum")
    h = LatencyHistogram()
    h.record_many(rng.uniform(1e-4, 1e-1, size=int(rng.integers(5, 40))))
    reg.attach_hist("dispatch.latency", h)
    reg.union("dispatch.shapes_seen", [(int(b), 5) for b in
                                       rng.choice([8, 16, 32], size=2)])
    reg.extend_samples("dispatch.batch_occupancy",
                       rng.integers(1, 64, size=5).tolist())
    return reg


def test_merge_order_independent_and_sums():
    parts = [_random_part(s) for s in range(5)]
    fwd = MetricsRegistry.merge(parts)
    rev = MetricsRegistry.merge(parts[::-1])
    # counters: bit-identical to the per-part integer sums, any order
    for name in ("ingest.pkts_total", "flow_table.drops"):
        want = sum(p.counter(name) for p in parts)
        assert fwd.counter(name) == want
        assert rev.counter(name) == want
    # gauges fold under their declared reduction
    assert fwd.gauge("flow_table.load_factor") == max(
        p.gauge("flow_table.load_factor") for p in parts)
    assert rev.gauge("flow_table.load_factor") == \
        fwd.gauge("flow_table.load_factor")
    # histogram counts are integer adds: exact and order-independent
    want_counts = sum(p.hist("dispatch.latency").counts() for p in parts)
    assert np.array_equal(fwd.hist("dispatch.latency").counts(), want_counts)
    assert np.array_equal(rev.hist("dispatch.latency").counts(), want_counts)
    assert fwd.hist("dispatch.latency").n == sum(
        p.hist("dispatch.latency").n for p in parts)
    # sets union; samples concatenate (statistics permutation-invariant)
    assert fwd.snapshot()["sets"] == rev.snapshot()["sets"]
    assert sorted(fwd._samples["dispatch.batch_occupancy"]) == \
        sorted(rev._samples["dispatch.batch_occupancy"])
    # merge is a pure read: parts' histograms were not mutated or aliased
    assert fwd.hist("dispatch.latency") is not parts[0].hist("dispatch.latency")


def test_merge_with_prefixes_keeps_per_shard_columns():
    parts = [_random_part(s) for s in range(3)]
    agg = MetricsRegistry.merge(parts, prefixes=[f"shard{i}." for i in range(3)])
    for i, p in enumerate(parts):
        assert agg.counter(f"shard{i}.ingest.pkts_total") == \
            p.counter("ingest.pkts_total")
    assert agg.counter("ingest.pkts_total") == \
        sum(p.counter("ingest.pkts_total") for p in parts)


def test_gauge_reduce_mismatch_raises():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.set_gauge("x", 1.0, reduce="sum")
    b.set_gauge("x", 2.0, reduce="max")
    with pytest.raises(ValueError, match="reduce mismatch"):
        MetricsRegistry.merge([a, b])


def test_fleet_merged_bit_identical_to_per_shard_sums(pipeline, stream,
                                                      service):
    """The satellite claim: `AggregateMetrics.merged` (now a registry
    round-trip) reproduces the hand-summed per-shard counters bit-for-bit,
    and the fleet registry carries the same totals."""
    created = []

    def mk():
        rt = fleet(pipeline, execute=False)
        created.append(rt)
        return rt

    stats = replay(stream, mk, 2e5, service)
    rt = created[-1]
    m = stats.metrics
    parts = rt.metrics.parts
    for f in RuntimeMetrics.counter_fields():
        assert getattr(m, f) == sum(getattr(p, f) for p in parts), f
    assert m.latency.n == sum(p.latency.n for p in parts)
    reg = fleet_registry(rt, per_shard=True)
    assert reg.counter("ingest.pkts_total") == m.pkts_total
    assert reg.counter("dispatch.batches") == m.batches
    assert sum(reg.counter(f"shard{i}.ingest.pkts_total")
               for i in range(rt.n_shards)) == m.pkts_total
    # merge permutation-invariance on the real fleet blocks (sample tails
    # concatenate in merge order, so compare those as multisets)
    fwd = MetricsRegistry.merge([p.to_registry() for p in parts]).snapshot()
    rev = MetricsRegistry.merge(
        [p.to_registry() for p in parts[::-1]]).snapshot()
    fs, rs = fwd.pop("samples"), rev.pop("samples")
    assert fwd == rev
    assert {k: sorted(v) for k, v in fs.items()} == \
        {k: sorted(v) for k, v in rs.items()}


# ---------------------------------------------------------------------------
# tracer: bounded ring, sampling, lifecycle spans
# ---------------------------------------------------------------------------


def test_ring_never_exceeds_capacity():
    tr = Tracer(capacity=8)
    for i in range(100):
        tr.span("s", float(i), 0.5)
    assert len(tr) == 8
    assert tr.total == 100
    assert tr.dropped == 92
    evs = tr.events()
    assert len(evs) == 8
    # oldest surviving event first, newest last (ring order preserved)
    assert [e["ts"] for e in evs] == [float(i) * 1e6 for i in range(92, 100)]


def test_sampling_deterministic_and_bounded():
    ids = np.arange(4000)
    tr = Tracer(sample=0.25, seed=1)
    keep = tr.sample_mask(ids)
    assert np.array_equal(keep, tr.sample_mask(ids))  # deterministic
    assert 0.15 < keep.mean() < 0.35
    assert Tracer(sample=0.0).sample_mask(ids).sum() == 0
    assert Tracer(sample=1.0).sample_mask(ids).all()


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    tr.span("s", 0.0, 1.0)
    tr.span_many("s", np.arange(4.0), np.ones(4))
    tr.instant("i", 0.0)
    tr.flow_begin(np.arange(3), np.zeros(3))
    tr.flow_end(np.arange(3), np.ones(3))
    assert tr.total == 0
    assert tr.summary() is None


def test_chrome_export_shape(tmp_path):
    tr = Tracer(capacity=64)
    tr.span("ingest.block", 0.0, 1e-3, pid=1, tid=0)
    tr.flow_begin(np.array([7]), np.array([0.0]), pid=1)
    tr.flow_end(np.array([7]), np.array([2e-3]), pid=1)
    doc = json.loads(tr.save(tmp_path / "t.json").read_text())
    evs = doc["traceEvents"]
    assert {"M", "X", "b", "e"} <= {e["ph"] for e in evs}
    x = next(e for e in evs if e["ph"] == "X")
    assert x["dur"] == pytest.approx(1e3)  # microseconds
    b = next(e for e in evs if e["ph"] == "b")
    assert b["cat"] == "flow" and b["id"] == 7


def test_trace_spans_nest_under_controlled_replay(ds, pipeline, pipeline_b,
                                                  stream, service):
    """One traced controlled replay with migrations and a mid-trace swap:
    every sampled flow's lifecycle must be well-formed (begin before every
    milestone before end) and stage spans non-negative on the right lanes."""
    svc_b = ServiceModel(
        pkt_accum_ns=1000.0, pkt_track_ns=250.0,
        bucket_ns={8: 4e4, 16: 5e4, 32: 7e4, 64: 1.2e5},
        gather_ns_per_flow=200.0, source="synthetic")
    cut = stream.n_events // 2
    cfg = ControlConfig(interval_pkts=512, imbalance_trigger=1.04,
                        swap=PipelineSwap(pipeline_b, svc_b, after_pkts=cut))
    obs = Observability(tracer=Tracer(capacity=1 << 15, sample=1.0),
                        drift=DriftMonitor())
    stats = controlled_replay(
        stream, lambda: fleet(pipeline, execute=True), stream.base_pps,
        service, session=ServeSession(control=cfg, obs=obs))
    assert stats.drops == 0
    assert stats.control["swaps"] == 1
    assert stats.control["rebalances"] > 0

    evs = obs.tracer.events()
    assert obs.tracer.dropped == 0  # capacity ample: nesting check is total
    begins, ends, marks = {}, {}, {}
    for e in evs:
        if e.get("cat") == "flow":
            if e["ph"] == "b":
                begins[e["id"]] = e["ts"]
            elif e["ph"] == "e":
                ends[e["id"]] = e["ts"]
            else:
                marks.setdefault(e["id"], []).append(e["ts"])
    # every flow that completed has one begin and one end, properly ordered
    assert set(ends) <= set(begins)
    assert len(ends) == len(stats.predictions)
    for fid, t_end in ends.items():
        assert begins[fid] <= t_end
        for t_mark in marks.get(fid, []):
            assert begins[fid] <= t_mark <= t_end
    # stage spans on the expected lanes, non-negative, swap visible
    xs = [e for e in evs if e["ph"] == "X"]
    assert all(e["dur"] >= 0 for e in xs)
    assert {e["name"] for e in xs if e["tid"] == 0} >= {"ingest.block"}
    infer_names = {e["name"] for e in xs if e["tid"] == 1}
    assert any(n.startswith("infer.") for n in infer_names)
    assert "infer.swap" in infer_names  # the quiesce flush was traced
    # control decisions appear as instants on the control lane
    insts = {e["name"] for e in evs if e["ph"] == "i"}
    assert "control.rebalance" in insts and "control.hot_swap" in insts

    # audit log covered every actuation the plane counted
    audit = obs.audit.summary()
    assert audit["rebalance"] == stats.control["rebalances"]
    assert audit["hot_swap"] == stats.control["swaps"]
    reb = obs.audit.of_kind("rebalance")[0]
    assert len(reb.before["shard_loads_ewma"]) == 4
    assert reb.after["imbalance"] < reb.before["imbalance"]


# ---------------------------------------------------------------------------
# audit log
# ---------------------------------------------------------------------------


def test_audit_validates_and_roundtrips(tmp_path):
    log = AuditLog()
    with pytest.raises(ValueError, match="unknown audit kind"):
        log.record("reboot", 0.0, "nope")
    log.record("rebalance", 1.0, "imbalance", {"moves": 3},
               before={"imbalance": 1.8}, after={"imbalance": 1.1})
    log.record("deploy", 2.0, "knee point", {"depth": 8})
    assert len(log) == 2
    assert [e.seq for e in log.events] == [0, 1]
    path = log.save(tmp_path / "audit.jsonl")
    back = AuditLog.load(path)
    assert [e.to_doc() for e in back.events] == \
        [e.to_doc() for e in log.events]
    assert back.summary() == {"events": 2, "rebalance": 1, "deploy": 1}


def test_deploy_and_make_swap_audit(ds, pipeline, stream, service):
    from repro.serve.deploy import BundlePoint, deploy, make_swap

    rep = FeatureRep(("dur", "s_load", "s_bytes_mean", "s_iat_mean",
                      "ack_cnt"), depth=8)
    point = BundlePoint(rep=rep, cost=1.0, perf=0.9, fidelity="measured",
                        aux={}, compile_meta={"fused": False},
                        forest_doc=None, pipeline=pipeline)
    log = AuditLog()
    session = ServeSession(audit=log)
    swap = make_swap(point, after_pkts=100, runtime=None, service=service,
                     session=session)
    assert swap.after_pkts == 100
    assert log.of_kind("swap_scheduled")[0].detail["after_pkts"] == 100
    rt = StreamingRuntime(pipeline, capacity=512, max_batch=32, execute=False)
    deploy(point, rt, 0.0, session=session)
    assert log.summary() == {"events": 2, "swap_scheduled": 1, "deploy": 1}


# ---------------------------------------------------------------------------
# drift signals
# ---------------------------------------------------------------------------


def test_streaming_moments_match_batch():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(500, 3)) * [1.0, 5.0, 0.1] + [0.0, 2.0, -1.0]
    sm = StreamingMoments(3)
    for lo in range(0, 500, 64):
        sm.update(X[lo:lo + 64])
    assert sm.n == 500
    np.testing.assert_allclose(sm.mean, X.mean(axis=0), rtol=1e-9)
    np.testing.assert_allclose(sm.var(), X.var(axis=0, ddof=1), rtol=1e-9)


def test_drift_monitor_synthetic_regime_change():
    rng = np.random.default_rng(1)
    dm = DriftMonitor(min_batches=4)
    for _ in range(30):  # stationary: classes 0/1 at 70/30
        dm.note_predictions(rng.choice(2, size=64, p=[0.7, 0.3]))
    flat = dm.signal()["max_class_shift"]
    for _ in range(10):  # regime change: class 2 takes over
        dm.note_predictions(np.full(64, 2))
    moved = dm.signal()["class_mix_shift"]
    assert flat < 0.15
    assert moved > 0.5
    assert moved > 4 * max(flat, 1e-6)


def test_drift_scenario_fires_uniform_stays_flat(service):
    """End to end: the same replay instrumented with a DriftMonitor sees a
    moving class mix under the `drift` scenario and a comparatively flat
    one under `uniform` (the ISSUE's acceptance signal)."""
    def signal_for(scenario):
        d = make_scenario_dataset("app-class", scenario, n_flows=400,
                                  max_pkts=32, seed=3)
        rep = FeatureRep(("dur", "s_load", "s_bytes_mean"), depth=8)
        pipe = _pipe(d, rep)
        st = PacketStream.from_dataset(d, seed=0)
        obs = Observability(drift=DriftMonitor())
        replay(st, lambda: StreamingRuntime(pipe, capacity=2048,
                                            max_batch=32, execute=True),
               2e5, service, session=ServeSession(obs=obs))
        sig = obs.drift.signal()
        assert sig["n_flows"] == 400
        return sig

    uni = signal_for("uniform")
    dri = signal_for("drift")
    assert dri["max_class_shift"] > 2 * uni["max_class_shift"]
    assert dri["max_class_shift"] > 0.4
    assert uni["max_class_shift"] < 0.35
    # feature sketches were fed from the dispatch arena in both runs
    assert uni["n_batches"] > 0 and dri["n_batches"] > 0


# ---------------------------------------------------------------------------
# stage accounting + bundle plumbing
# ---------------------------------------------------------------------------


def test_stage_seconds_partition_busy_time(pipeline, stream, service):
    stats = replay(stream,
                   lambda: StreamingRuntime(pipeline, capacity=2048,
                                            max_batch=64, execute=False),
                   2e5, service)
    ss = stats.stage_seconds
    assert set(ss) == {"ingest", "infer", "flush"}
    assert all(v >= 0 for v in ss.values()) and sum(ss.values()) > 0
    assert sum(stats.stage_shares().values()) == pytest.approx(1.0)


def test_per_shard_stage_rows(pipeline, stream, service):
    stats = replay(stream, lambda: fleet(pipeline), 2e5, service)
    assert len(stats.per_shard) == 4
    for row in stats.per_shard:
        assert set(row["stage_seconds"]) == {"ingest", "infer", "flush"}
    agg = {k: sum(r["stage_seconds"][k] for r in stats.per_shard)
           for k in ("ingest", "infer", "flush")}
    for k, v in stats.stage_seconds.items():
        assert v == pytest.approx(agg[k])


def test_hot_swap_and_scale_out_carry_hooks(pipeline, pipeline_b):
    obs = Observability(tracer=Tracer(capacity=64), drift=DriftMonitor())
    rt = fleet(pipeline, n_shards=2)
    obs.attach(rt)
    rt.hot_swap(pipeline_b, now=0.0)
    for w in rt.shards:
        assert w.dispatcher.tracer is obs.tracer
        assert w.dispatcher.drift is obs.drift
    i = rt.add_worker()
    assert rt.shards[i].dispatcher.tracer is obs.tracer
    assert rt.shards[i].dispatcher.trace_pid == i


def test_snapshot_document(pipeline, stream, service):
    obs = Observability(tracer=Tracer(capacity=1 << 12, sample=0.5),
                        drift=DriftMonitor())
    created = []

    def mk():
        rt = fleet(pipeline, execute=False)
        created.append(rt)
        return rt

    stats = replay(stream, mk, 2e5, service,
                   session=ServeSession(
                       control=ControlConfig(interval_pkts=512), obs=obs))
    doc = obs.snapshot(created[-1])
    assert doc["registry"]["counters"]["ingest.pkts_total"] == \
        stats.metrics.pkts_total
    assert doc["trace"]["events"] > 0
    json.dumps(doc)  # artifact contract: snapshot is JSON-ready

"""CATO Optimizer behaviour on a controlled toy problem."""
import numpy as np
import pytest

from repro.core import (
    CatoOptimizer, FeatureRep, SearchSpace, build_priors, hvi_ratio,
)
from repro.core.baselines import (
    run_iterate_all, run_random_search, run_simulated_annealing,
    select_all, select_mi_topk, select_rfe_topk,
)

NAMES = tuple(f"f{i}" for i in range(6))
VALUE = np.array([0.6, 0.35, 0.15, 0.05, 0.0, 0.0])
COST = np.array([1.0, 6.0, 0.3, 3.0, 10.0, 0.5])


def profiler(x: FeatureRep):
    # mirrors the traffic landscape: perf saturates after ~6 packets
    # (the regime the Beta(1,2) depth prior encodes), cost keeps growing
    idx = [NAMES.index(f) for f in x.features]
    perf = 1 - np.exp(-VALUE[idx].sum() * (1 + 0.5 * min(x.depth, 6) / 6))
    cost = COST[idx].sum() * (1 + 0.08 * x.depth)
    return cost, perf


def true_front(space):
    Y = np.array([[profiler(x)[0], -profiler(x)[1]]
                  for x in space.enumerate_all()])
    return Y


@pytest.fixture(scope="module")
def space():
    return SearchSpace(NAMES, max_depth=20)


@pytest.fixture(scope="module")
def toy_priors(space):
    # NB: local generator — the session rng's state depends on test order
    rng = np.random.default_rng(42)
    y = rng.integers(0, 2, 1500)
    X = np.stack([y * VALUE[i] * 3 + rng.normal(0, 1, 1500) for i in range(6)], 1)
    return build_priors(space, X, y)


def test_bo_beats_random_at_equal_budget(space, toy_priors):
    truth = true_front(space)
    h_bo, h_rs = [], []
    for seed in (0, 1, 2):
        res_bo = CatoOptimizer(space, profiler, toy_priors, seed=seed).run(30)
        res_rs = run_random_search(space, profiler, 30, seed=seed)
        h_bo.append(hvi_ratio(
            np.array([o.objectives for o in res_bo.observations]), truth))
        h_rs.append(hvi_ratio(
            np.array([o.objectives for o in res_rs.observations]), truth))
    assert min(h_bo) > 0.8
    # on average BO should not lose to random (single seeds can tie/flip)
    assert np.mean(h_bo) >= np.mean(h_rs) - 0.02


def test_all_search_algorithms_return_valid_results(space):
    for runner in (
        lambda: run_random_search(space, profiler, 10, seed=1),
        lambda: run_iterate_all(space, profiler, 10),
        lambda: run_simulated_annealing(space, profiler, 10, seed=1),
    ):
        res = runner()
        assert len(res.observations) == 10
        front = res.pareto_points()
        assert front.shape[1] == 2
        # front sorted by cost and non-dominated
        assert (np.diff(front[:, 0]) >= 0).all()
        assert (np.diff(front[:, 1]) >= 0).all()


def test_point_selectors(space, rng):
    y = rng.integers(0, 2, 800)
    X = np.stack([y * VALUE[i] * 3 + rng.normal(0, 1, 800) for i in range(6)], 1)
    assert len(select_all(space, 10).features) == 6
    mi = select_mi_topk(space, 10, X, y, k=2)
    assert len(mi.features) == 2
    assert "f0" in mi.features  # strongest signal survives
    rfe = select_rfe_topk(space, 10, X, y, k=3)
    assert len(rfe.features) == 3


def test_observation_cache_and_dedup(space, toy_priors):
    opt = CatoOptimizer(space, profiler, toy_priors, seed=2)
    res = opt.run(15)
    keys = [o.x.key() for o in res.observations]
    assert len(keys) == len(set(keys)), "re-evaluated an already-seen point"

"""Pareto / hypervolume invariants (hypothesis property tests)."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
except ImportError:  # seeded-sampling fallback, see tests/_hypothesis_shim.py
    from _hypothesis_shim import given, hnp, settings, strategies as st

from repro.core.pareto import (
    hvi_ratio, hypervolume_2d, normalize_objectives, pareto_front,
)

pts = hnp.arrays(
    np.float64, st.tuples(st.integers(1, 60), st.just(2)),
    elements=st.floats(0, 1, allow_nan=False),
)


@settings(max_examples=50, deadline=None)
@given(Y=pts)
def test_front_is_nondominated(Y):
    P = pareto_front(Y)
    for i in range(len(P)):
        dom = np.all(P <= P[i], axis=1) & np.any(P < P[i], axis=1)
        assert not dom.any()


@settings(max_examples=50, deadline=None)
@given(Y=pts)
def test_front_members_come_from_input(Y):
    P = pareto_front(Y)
    for p in P:
        assert np.any(np.all(np.isclose(Y, p), axis=1))


@settings(max_examples=50, deadline=None)
@given(Y=pts)
def test_hv_of_front_equals_hv_of_set(Y):
    assert np.isclose(hypervolume_2d(pareto_front(Y)), hypervolume_2d(Y))


@settings(max_examples=50, deadline=None)
@given(Y=pts, extra=pts)
def test_hv_monotone_under_union(Y, extra):
    both = np.concatenate([Y, extra])
    assert hypervolume_2d(both) >= hypervolume_2d(Y) - 1e-12


@settings(max_examples=30, deadline=None)
@given(Y=pts)
def test_hv_bounded_by_ref_box(Y):
    hv = hypervolume_2d(Y, ref=(1.0, 1.0))
    assert 0.0 <= hv <= 1.0 + 1e-12


def test_hv_known_value():
    # single point at (0.5, 0.5) with ref (1,1): area 0.25
    assert np.isclose(hypervolume_2d(np.array([[0.5, 0.5]])), 0.25)
    # staircase
    front = np.array([[0.2, 0.8], [0.5, 0.4], [0.9, 0.1]])
    hv = (1 - 0.2) * (1 - 0.8) + (1 - 0.5) * (0.8 - 0.4) + (1 - 0.9) * (0.4 - 0.1)
    assert np.isclose(hypervolume_2d(front), hv)


@settings(max_examples=30, deadline=None)
@given(Y=pts)
def test_hvi_ratio_self_is_one(Y):
    if hypervolume_2d(*normalize_objectives(Y)[:1]) > 0:
        assert np.isclose(hvi_ratio(Y, Y), 1.0)


@settings(max_examples=30, deadline=None)
@given(Y=pts)
def test_subset_hvi_at_most_one(Y):
    sub = Y[: max(1, len(Y) // 2)]
    assert hvi_ratio(sub, Y) <= 1.0 + 1e-9


def test_hvi_contribution_matches_hv_delta(rng):
    from repro.core.acquisition import hvi_contribution

    front = pareto_front(rng.random((20, 2)))
    cands = rng.random((50, 2))
    contrib = hvi_contribution(front, cands)
    base = hypervolume_2d(front)
    for c, pt in zip(contrib, cands):
        truth = hypervolume_2d(np.vstack([front, pt])) - base
        assert np.isclose(c, truth, atol=1e-9), (c, truth, pt)

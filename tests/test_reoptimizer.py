"""Drift-triggered re-optimization (DESIGN.md §13): the episode state
machine (one fire per excursion, hysteresis release, cooldown refractory),
the shadow-evaluation guard, and the closed loop end to end — a drifting
replay triggers exactly one audited episode whose swap preserves
prediction parity with a fleet deployed directly on the new knee, while
a uniform replay triggers none."""
import numpy as np
import pytest

from repro.core.search_space import FeatureRep
from repro.serve import (
    ControlConfig,
    ControlPlane,
    DriftMonitor,
    DriftVerdict,
    Observability,
    PacketStream,
    ReoptOutcome,
    ReoptimizerConfig,
    ReoptimizerPolicy,
    ServeSession,
    ServiceModel,
    ShardedRuntime,
    replay,
)
from repro.serve.deploy import BundlePoint
from repro.traffic import extract_features
from repro.traffic.models import train_traffic_model
from repro.traffic.pipeline import build_pipeline
from repro.traffic.synth import make_scenario_dataset

REP_A = FeatureRep(("dur", "s_load", "s_bytes_mean", "s_iat_mean",
                    "ack_cnt"), depth=8)
REP_B = FeatureRep(("dur", "s_load", "s_pkt_cnt", "d_bytes_med",
                    "psh_cnt"), depth=12)


@pytest.fixture(scope="module")
def ds():
    # class mix slides along the replay: the excursion the policy hunts
    return make_scenario_dataset("app-class", "drift", n_flows=600,
                                 max_pkts=32, seed=3)


def _pipe(ds, rep):
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="tree-fast", seed=0)
    return build_pipeline(rep, forest, max_pkts=rep.depth, use_kernel=False)


@pytest.fixture(scope="module")
def pipeline(ds):
    return _pipe(ds, REP_A)


@pytest.fixture(scope="module")
def pipeline_b(ds):
    return _pipe(ds, REP_B)


@pytest.fixture(scope="module")
def stream(ds):
    return PacketStream.from_dataset(ds, seed=0)


@pytest.fixture(scope="module")
def service():
    return ServiceModel(
        pkt_accum_ns=800.0, pkt_track_ns=200.0,
        bucket_ns={8: 3e4, 16: 4e4, 32: 6e4, 64: 1e5},
        gather_ns_per_flow=200.0, source="synthetic",
    )


def _point(rep, pipe):
    return BundlePoint(rep=rep, cost=1.0, perf=0.95, fidelity="measured",
                       aux={}, compile_meta={"fused": False},
                       forest_doc=None, pipeline=pipe)


def _verdict(trig, armed):
    return DriftVerdict(
        triggered=trig, armed=armed, warmed_up=True,
        class_mix_shift=0.4 if trig else (0.2 if armed else 0.0),
        feature_shift=0.0, class_threshold=0.25,
        feature_threshold=float("inf"))


class ScriptedDrift:
    """DriftMonitor stand-in emitting a scripted verdict sequence
    (the last entry repeats once the script runs out)."""

    def __init__(self, script):
        self.script = list(script)
        self.rebaselines = 0

    def check(self, class_threshold=0.25, feature_threshold=float("inf"),
              *, release_frac=0.5):
        trig, armed = (self.script.pop(0) if len(self.script) > 1
                       else self.script[0])
        return _verdict(trig, armed)

    def signal(self):
        return {"scripted": True}

    def rebaseline(self):
        self.rebaselines += 1


def _plane(pipeline, service, policy, drift, interval=64):
    rt = ShardedRuntime(pipeline, n_shards=2, capacity=2048,
                        max_batch=64, execute=False)
    session = ServeSession(obs=Observability(drift=drift), reopt=policy)
    return ControlPlane(
        rt, ControlConfig(interval_pkts=interval, rebalance=False),
        service, session=session)


def _drive(plane, n_steps, interval=64):
    """Feed `interval` packets per step and run the control step."""
    buckets = np.arange(interval, dtype=np.int64) % 8
    keys = np.arange(interval, dtype=np.uint64)
    for k in range(n_steps):
        plane.note(keys, buckets)
        plane.maybe_step(float(k + 1))


# ---------------------------------------------------------------------------
# episode state machine
# ---------------------------------------------------------------------------


def test_episode_fires_once_then_cooldown_blocks(pipeline, pipeline_b,
                                                 service):
    calls = []

    def retune(trigger):
        calls.append(trigger)
        return ReoptOutcome(point=_point(REP_B, pipeline_b),
                            service=service,
                            budget={"measure_evals": 0},
                            old_objectives=(1.0, 0.9),
                            new_objectives=(1.1, 0.95))

    drift = ScriptedDrift([(True, True)])
    policy = ReoptimizerPolicy(retune, ReoptimizerConfig(
        min_dwell_pkts=64, cooldown_pkts=1 << 20, max_episodes=4))
    plane = _plane(pipeline, service, policy, drift)
    _drive(plane, 10)

    # many triggered steps, ONE episode: cooldown swallows the rest
    assert len(policy.episodes) == 1
    assert len(calls) == 1
    assert policy.state == "cooldown"
    assert policy.n_suppressed_cooldown > 0
    # the armed swap fired through the plane's normal path on a later step
    assert plane.n_swaps == 1
    assert plane.swap_at_pkts is not None
    # trigger document carries the clock and the drift evidence
    assert calls[0]["episode"] == 0
    assert calls[0]["pkts_ingested"] >= 64
    assert calls[0]["verdict"]["triggered"] is True
    # audited: reopt episode + the swap it scheduled + the hot_swap fire
    kinds = [e.kind for e in plane.audit.events]
    assert kinds.count("reopt") == 1
    assert kinds.count("swap_scheduled") == 1
    assert kinds.count("hot_swap") == 1
    ep = plane.audit.of_kind("reopt")[0]
    assert ep.detail["old_knee"] == [1.0, 0.9]
    assert ep.detail["new_knee"] == [1.1, 0.95]
    assert ep.detail["budget"] == {"measure_evals": 0}
    assert ep.detail["drift"]["class_mix_shift"] == pytest.approx(0.4)
    # the fix re-anchors the baseline exactly once
    assert drift.rebaselines == 1
    # summary + registry projection
    assert plane.summary()["reopt"]["episodes"] == 1
    snap = policy.to_registry().snapshot()
    assert snap["counters"]["reopt.episodes"] == 1
    assert snap["counters"]["reopt.triggers"] == 1


def test_hysteresis_release_cancels_dwell(pipeline, service):
    def retune(trigger):  # must never run
        raise AssertionError("released excursion must not re-tune")

    # trigger opens a dwell, then the signal drops out of the hysteresis
    # band before the dwell floor fills -> back to idle, no episode
    drift = ScriptedDrift([(True, True), (False, False), (False, False)])
    policy = ReoptimizerPolicy(retune, ReoptimizerConfig(
        min_dwell_pkts=1 << 16))
    plane = _plane(pipeline, service, policy, drift)
    _drive(plane, 6)
    assert policy.episodes == []
    assert policy.n_triggers == 1
    assert policy.n_disarmed == 1
    assert policy.state == "idle"


def test_hysteresis_hold_keeps_dwell_open(pipeline, pipeline_b, service):
    # after the trigger the signal dips below the threshold but stays in
    # the armed band: the dwell must survive the dip and fire
    drift = ScriptedDrift([(True, True), (False, True)])
    policy = ReoptimizerPolicy(
        lambda trigger: ReoptOutcome(point=_point(REP_B, pipeline_b),
                                     service=service),
        ReoptimizerConfig(min_dwell_pkts=128, cooldown_pkts=1 << 20))
    plane = _plane(pipeline, service, policy, drift)
    _drive(plane, 8)
    assert len(policy.episodes) == 1
    assert policy.n_disarmed == 0


def test_cooldown_expiry_allows_next_excursion(pipeline, pipeline_b,
                                               service):
    drift = ScriptedDrift([(True, True)])
    policy = ReoptimizerPolicy(
        lambda trigger: ReoptOutcome(point=_point(REP_B, pipeline_b),
                                     service=service),
        ReoptimizerConfig(min_dwell_pkts=64, cooldown_pkts=192,
                          max_episodes=2))
    plane = _plane(pipeline, service, policy, drift)
    _drive(plane, 16)
    # two distinct excursions (cooldown elapsed between them), two swaps
    assert len(policy.episodes) == 2
    assert plane.n_swaps == 2
    # and the cap stops a third
    assert policy.state == "cooldown" or len(policy.episodes) == 2


def test_max_episodes_caps_the_run(pipeline, pipeline_b, service):
    drift = ScriptedDrift([(True, True)])
    policy = ReoptimizerPolicy(
        lambda trigger: ReoptOutcome(point=_point(REP_B, pipeline_b),
                                     service=service),
        ReoptimizerConfig(min_dwell_pkts=64, cooldown_pkts=64,
                          max_episodes=1))
    plane = _plane(pipeline, service, policy, drift)
    _drive(plane, 16)
    assert len(policy.episodes) == 1


def test_reset_clears_episode_history(pipeline, pipeline_b, service):
    drift = ScriptedDrift([(True, True)])
    policy = ReoptimizerPolicy(
        lambda trigger: ReoptOutcome(point=_point(REP_B, pipeline_b),
                                     service=service),
        ReoptimizerConfig(min_dwell_pkts=64))
    plane = _plane(pipeline, service, policy, drift)
    _drive(plane, 6)
    assert len(policy.episodes) == 1
    # a fresh plane (new replay / bisection probe) resets the policy:
    # no episode history leaks across runs
    drift2 = ScriptedDrift([(False, False)])
    plane2 = _plane(pipeline, service, policy, drift2)
    assert policy.episodes == []
    assert policy.state == "idle"
    assert policy.drift is drift2
    _drive(plane2, 2)
    assert policy.episodes == []


def test_shadow_guard_rejects_live_fleet_evaluation(pipeline, service):
    def dirty_retune(trigger):
        # a re-tune body that "measures" on the live fleet moves its
        # counters — the guard must catch exactly this
        plane.rt.shards[0].metrics.pkts_total += 1
        return ReoptOutcome(point=_point(REP_A, pipeline))

    drift = ScriptedDrift([(True, True)])
    policy = ReoptimizerPolicy(dirty_retune, ReoptimizerConfig(
        min_dwell_pkts=64))
    plane = _plane(pipeline, service, policy, drift)
    with pytest.raises(RuntimeError, match="live fleet"):
        _drive(plane, 6)


# ---------------------------------------------------------------------------
# closed loop, end to end
# ---------------------------------------------------------------------------


def _selftune_session(policy):
    return ServeSession(
        obs=Observability(drift=DriftMonitor()),
        control=ControlConfig(interval_pkts=256, rebalance=False),
        reopt=policy,
    )


def _run(stream, pipe, service, session=None, pps=2e5):
    # max_batch must be small enough that micro-batches flush (and their
    # deferred resolutions feed the drift monitor) *mid-run* — at 64 the
    # whole trace fits in a couple of batches per shard and every
    # prediction resolves at drain, after the last control step
    return replay(
        stream,
        lambda: ShardedRuntime(pipe, n_shards=2, capacity=2048,
                               max_batch=16, execute=True),
        pps, service, session=session)


def test_drifting_replay_fires_one_episode_with_prediction_parity(
        ds, pipeline, pipeline_b, stream, service):
    policy = ReoptimizerPolicy(
        lambda trigger: ReoptOutcome(point=_point(REP_B, pipeline_b),
                                     service=service),
        # 0.35 sits between the uniform arm's small-batch noise ceiling
        # (~0.25 TV at max_batch=16) and the drift excursion (>0.6)
        ReoptimizerConfig(class_threshold=0.35, min_dwell_pkts=256,
                          cooldown_pkts=1 << 20, max_episodes=1))
    stats = _run(stream, pipeline, service, _selftune_session(policy))

    assert stats.control["reopt"]["episodes"] == 1
    assert stats.control["swaps"] == 1
    assert stats.drops == 0
    swap_at = stats.control["swap_at_pkts"]
    assert swap_at is not None

    # every flow the fleet saw got exactly one prediction through the swap
    assert len(stats.predictions) == ds.n_flows

    # flows that began after the swap classify bit-identically to a fleet
    # deployed directly on the new knee (§9.3 exactly-once + §13 parity)
    direct = _run(stream, pipeline_b, service)
    first_pkt = np.full(ds.n_flows, stream.n_events)
    np.minimum.at(first_pkt, stream.fid, np.arange(stream.n_events))
    post = np.nonzero(first_pkt >= swap_at)[0]
    assert len(post) > 0
    for fid in post:
        assert stats.predictions[fid] == direct.predictions[fid]


def test_uniform_replay_fires_zero_episodes(service, pipeline_b):
    d = make_scenario_dataset("app-class", "uniform", n_flows=600,
                              max_pkts=32, seed=3)
    pipe = _pipe(d, REP_A)
    st = PacketStream.from_dataset(d, seed=0)
    policy = ReoptimizerPolicy(
        lambda trigger: ReoptOutcome(point=_point(REP_B, pipeline_b),
                                     service=service),
        ReoptimizerConfig(class_threshold=0.35, min_dwell_pkts=256))
    session = _selftune_session(policy)
    stats = _run(st, pipe, service, session)
    assert stats.control["reopt"]["episodes"] == 0
    assert stats.control["swaps"] == 0
    assert session.resolve_audit().of_kind("reopt") == []

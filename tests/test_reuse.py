"""Incremental aggregate state and drift-gated prediction reuse
(DESIGN.md §12).

The contracts under test:

- slot hygiene: recycling a slot resets *every* per-slot column, so a
  re-tenant flow can never inherit aggregate (or any other) state;
- incremental ≡ full recompute: a reuse table's deferred-fold arena
  produces the same aggregate block as the eager per-packet Welford
  reference — exact for count/sum/min/max cells, ≤1e-6 relative for the
  variance-carrying M2 cells (Chan merge reassociates the float sums) —
  across arena overflow, idle eviction, FIN re-tenancy and `move_slot`
  migration;
- chunk invariance: scalar `observe` and `observe_batch` at any chunking
  agree on all control/payload state and on the aggregate block;
- threshold-0 parity: drift threshold 0 forces re-inference at every
  refresh, and the runtime's per-flow predictions are bit-identical to
  the non-reuse path (first prediction wins either way);
- the incremental inference entry: the fused kernel's aggregate-block
  path matches the unfused reference path on the same rows.
"""

import numpy as np
import pytest

from repro.core.search_space import FeatureRep
from repro.serve.runtime import (
    FlowStatus,
    FlowTable,
    PacketStream,
    ReuseConfig,
    RuntimeMetrics,
    ServiceModel,
    StreamingRuntime,
    move_slot,
    replay,
)
from repro.traffic import extract_features, make_dataset
from repro.traffic.models import train_traffic_model
from repro.traffic.pipeline import build_pipeline

# variance-carrying cells of the aggregate block: per-direction M2 for
# bytes/winsize/ttl and IAT (base d*20 + {4, 8, 12, 17})
M2_COLS = (4, 8, 12, 17, 24, 28, 32, 37)

DEPTH = 8


def _synth_packets(n_flows=60, n_pkts=4000, seed=0, fin_flows=15):
    """Zipf-ish interleaved packet arrays with mid-stream double-FIN
    closes on the hottest flows (forces recycle + re-tenancy)."""
    rng = np.random.default_rng(seed)
    keys_pool = rng.integers(1, 2**63, n_flows).astype(np.uint64)
    w = 1.0 / np.arange(1, n_flows + 1) ** 1.1
    w /= w.sum()
    fidx = rng.choice(n_flows, n_pkts, p=w)
    t = np.cumsum(rng.random(n_pkts) * 1e-4)
    fin = np.zeros(n_pkts, bool)
    dirn = rng.integers(0, 2, n_pkts).astype(np.int64)
    for f in range(fin_flows):
        hits = np.flatnonzero(fidx == f)
        if hits.size > 20:
            mid = hits[hits.size // 2]
            fin[mid] = True
            dirn[mid] = 0
            later = hits[hits > mid]
            if later.size:
                fin[later[0]] = True
                dirn[later[0]] = 1
    return dict(
        key=keys_pool[fidx],
        t=t,
        rel=t.astype(np.float32).astype(np.float64),
        size=rng.integers(40, 1500, n_pkts).astype(np.float64),
        dirn=dirn,
        ttl=rng.integers(30, 128, n_pkts).astype(np.float64),
        win=rng.integers(0, 65535, n_pkts).astype(np.float64),
        fb=rng.integers(0, 256, n_pkts).astype(np.int64),
        fin=fin,
        proto=np.full(n_pkts, 6.0),
        sp=rng.integers(1024, 65535, n_pkts).astype(np.float64),
        dp=np.full(n_pkts, 443.0),
        fid=fidx.astype(np.int64),
    )


def _feed_block(tbl, p, lo, hi):
    s = slice(lo, hi)
    st, sl, _ = tbl.observe_batch(
        p["key"][s], p["t"][s], p["rel"][s], p["size"][s], p["dirn"][s],
        p["ttl"][s], p["win"][s], p["fb"][s], p["proto"][s], p["sp"][s],
        p["dp"][s], p["fid"][s], p["fin"][s])
    ready = np.flatnonzero((st == int(FlowStatus.READY))
                           | (st == int(FlowStatus.READY_EOF)))
    if ready.size:
        tbl.mark_predicted(sl[ready])
    tbl.take_refresh_due()


def _feed_scalar(tbl, p, lo, hi):
    for i in range(lo, hi):
        st, sl = tbl.observe(
            int(p["key"][i]), float(p["t"][i]), float(p["rel"][i]),
            float(p["size"][i]), int(p["dirn"][i]), float(p["ttl"][i]),
            float(p["win"][i]), int(p["fb"][i]), float(p["proto"][i]),
            float(p["sp"][i]), float(p["dp"][i]), int(p["fid"][i]),
            bool(p["fin"][i]))
        if st in (FlowStatus.READY, FlowStatus.READY_EOF):
            tbl.mark_predicted(np.array([sl]))
        tbl.take_refresh_due()


def _assert_agg_close(a, b, tag=""):
    ex = np.ones(a.shape[1], bool)
    ex[list(M2_COLS)] = False
    assert np.array_equal(a[:, ex], b[:, ex]), f"{tag}: non-M2 agg cells"
    d = np.abs(a[:, ~ex] - b[:, ~ex])
    r = d / np.maximum(np.abs(a[:, ~ex]), 1e-30)
    assert not ((d > 1e-9) & (r > 1e-6)).any(), f"{tag}: M2 drifted"


# ---------------------------------------------------------------------------
# slot hygiene
# ---------------------------------------------------------------------------

def test_recycle_resets_every_column():
    """Allocate, dirty every per-slot surface, recycle, re-allocate: the
    re-tenant's slot state must be bitwise what a fresh table produces."""
    p = _synth_packets(n_flows=6, n_pkts=600, seed=3, fin_flows=6)
    dirty = FlowTable(64, DEPTH, reuse=True, refresh_every=16, agg_buffer=64)
    _feed_block(dirty, p, 0, 600)  # FINs inside recycle predicted flows
    assert dirty.metrics.slots_recycled > 0

    # second tenancy: a fresh key stream into the dirtied table vs a
    # pristine table — every per-slot array must agree at the new slots
    q = _synth_packets(n_flows=6, n_pkts=400, seed=11, fin_flows=0)
    q["key"] = q["key"] + np.uint64(7)  # distinct tenancy keys
    fresh = FlowTable(64, DEPTH, reuse=True, refresh_every=16, agg_buffer=64)
    _feed_block(dirty, q, 0, 400)
    _feed_block(fresh, q, 0, 400)
    dirty.flush_agg()
    fresh.flush_agg()

    for k in np.unique(q["key"]):
        sd = int(np.flatnonzero(dirty.ctrl["key"] == k)[0])
        sf = int(np.flatnonzero(fresh.ctrl["key"] == k)[0])
        assert dirty.ctrl[sd] == fresh.ctrl[sf]
        for f in ("ts", "size", "direction", "ttl", "winsize", "flags",
                  "proto", "s_port", "d_port", "agg", "anchor"):
            a, b = getattr(dirty, f), getattr(fresh, f)
            if a is None:  # anchor only allocated when anchor_dim > 0
                assert b is None
                continue
            assert np.array_equal(a[sd], b[sf]), f
        assert dirty.anchor_valid[sd] == fresh.anchor_valid[sf]
        assert dirty.refresh_pending[sd] == fresh.refresh_pending[sf]


def test_clear_slot_restores_pristine_row():
    """A recycled slot's aggregate/anchor rows equal a never-used slot's."""
    p = _synth_packets(n_flows=3, n_pkts=200, seed=5, fin_flows=0)
    tbl = FlowTable(64, DEPTH, reuse=True, refresh_every=8, agg_buffer=32,
                    anchor_dim=5)
    _feed_block(tbl, p, 0, 200)
    tbl.flush_agg()
    used = int(np.flatnonzero(tbl.ctrl["state"] != 0)[0])
    never = int(np.flatnonzero(tbl.ctrl["state"] == 0)[-1])
    tbl.anchor[used] = 3.25  # dirty the drift anchor too
    tbl.anchor_valid[used] = True
    assert not np.array_equal(tbl.agg[used], tbl.agg[never])
    tbl.recycle(used)
    assert np.array_equal(tbl.agg[used], tbl.agg[never])
    assert np.array_equal(tbl.anchor[used], tbl.anchor[never])
    assert not tbl.anchor_valid[used]
    assert tbl.ctrl[used] == tbl.ctrl[never]


# ---------------------------------------------------------------------------
# incremental ≡ full recompute / chunk invariance
# ---------------------------------------------------------------------------

def _cmp_tables(a, b, tag):
    for f in ("key", "state", "seen", "count", "fin_mask", "last_ts",
              "flow_id"):
        assert np.array_equal(a.ctrl[f], b.ctrl[f]), f"{tag}: ctrl[{f}]"
    for f in ("ts", "size", "direction", "ttl", "winsize", "flags",
              "proto", "s_port", "d_port"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f"{tag}: {f}"
    _assert_agg_close(a.agg, b.agg, tag)


@pytest.mark.parametrize("chunk", [1, 7, 257])
def test_deferred_fold_matches_eager_reference(chunk):
    """Reuse table (deferred-fold arena, odd capacity to force overflow
    splits) vs the eager per-packet Welford reference (track_agg only),
    same stream with FIN re-tenancy and mid-stream idle eviction."""
    p = _synth_packets()
    n = len(p["t"])
    ref = FlowTable(256, DEPTH, idle_timeout_s=0.05, track_agg=True,
                    metrics=RuntimeMetrics())
    inc = FlowTable(256, DEPTH, idle_timeout_s=0.05, reuse=True,
                    refresh_every=32, agg_buffer=257,
                    metrics=RuntimeMetrics())
    evict_at = {n // 3, 2 * n // 3}
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        _feed_block(ref, p, lo, hi)
        _feed_block(inc, p, lo, hi)
        if any(lo < e <= hi for e in evict_at):
            now = float(p["t"][hi - 1])
            ref.evict_idle(now)
            inc.evict_idle(now)
    inc.flush_agg()
    _cmp_tables(ref, inc, f"chunk={chunk}")


def test_scalar_and_block_agree_across_chunkings():
    """observe() vs observe_batch at several chunk sizes: identical
    control/payload state, identical aggregates up to M2 merge order."""
    p = _synth_packets()
    base = FlowTable(256, DEPTH, reuse=True, refresh_every=32,
                     agg_buffer=257, metrics=RuntimeMetrics())
    _feed_scalar(base, p, 0, len(p["t"]))
    base.flush_agg()
    for chunk in (1, 128, 512):
        tbl = FlowTable(256, DEPTH, reuse=True, refresh_every=32,
                        agg_buffer=257, metrics=RuntimeMetrics())
        for lo in range(0, len(p["t"]), chunk):
            _feed_block(tbl, p, lo, min(lo + chunk, len(p["t"])))
        tbl.flush_agg()
        _cmp_tables(base, tbl, f"chunk={chunk}")


def test_move_slot_migrates_aggregates():
    """Mid-stream migration of every live flow to a fresh table: the
    migrated fleet finishes with the same aggregates as an unmigrated
    eager reference."""
    p = _synth_packets(n_flows=24, n_pkts=2400, seed=7)
    n = len(p["t"])
    ref = FlowTable(256, DEPTH, track_agg=True, metrics=RuntimeMetrics())
    src = FlowTable(256, DEPTH, reuse=True, refresh_every=32, agg_buffer=97,
                    metrics=RuntimeMetrics())
    _feed_block(ref, p, 0, n // 2)
    _feed_block(src, p, 0, n // 2)

    dst = FlowTable(256, DEPTH, reuse=True, refresh_every=32, agg_buffer=97,
                    metrics=RuntimeMetrics())
    for s in np.flatnonzero(src.ctrl["state"] != 0):
        move_slot(src, dst, int(s))
    assert src.metrics.flows_migrated_out == dst.metrics.flows_migrated_in > 0

    _feed_block(ref, p, n // 2, n)
    _feed_block(dst, p, n // 2, n)
    ref.flush_agg()
    dst.flush_agg()
    for k in np.unique(p["key"]):
        rs = np.flatnonzero(ref.ctrl["key"] == k)
        ds_ = np.flatnonzero(dst.ctrl["key"] == k)
        if not rs.size or not ds_.size:
            assert rs.size == ds_.size, f"key {k} liveness diverged"
            continue
        a, b = ref.agg[rs[0]][None, :], dst.agg[ds_[0]][None, :]
        _assert_agg_close(a, b, f"key {k}")
        assert ref.ctrl["seen"][rs[0]] == dst.ctrl["seen"][ds_[0]]


# ---------------------------------------------------------------------------
# threshold-0 bit parity / incremental inference entry
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ds():
    return make_dataset("app-class", n_flows=200, max_pkts=48, seed=9)


@pytest.fixture(scope="module")
def stream(ds):
    return PacketStream.from_dataset(ds, seed=1)


@pytest.fixture(scope="module")
def pipeline(ds):
    rep = FeatureRep(("dur", "s_load", "s_bytes_mean", "s_iat_mean",
                      "ack_cnt"), depth=DEPTH)
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="tree-fast", seed=0)
    return build_pipeline(rep, forest, max_pkts=rep.depth, use_kernel=False)


def test_threshold_zero_predictions_bit_identical(pipeline, stream):
    """Drift threshold 0 re-infers at every refresh, and `results` keeps
    first-prediction-wins: executing replays with and without reuse must
    emit bit-identical per-flow predictions."""
    svc = ServiceModel.modeled(pipeline.rep, pipeline.forest)

    def mk(ru):
        return lambda: StreamingRuntime(
            pipeline, capacity=1024, max_batch=16, execute=True, reuse=ru)

    base = replay(stream, mk(None), stream.base_pps, svc, ring_capacity=512)
    thr0 = replay(
        stream,
        mk(ReuseConfig(enabled=True, drift_threshold=0.0, refresh_every=16)),
        stream.base_pps, svc, ring_capacity=512)
    assert thr0.metrics.forced_reinfer > 0  # the parity mode actually ran
    assert set(base.predictions) == set(thr0.predictions)
    for k in base.predictions:
        assert np.array_equal(base.predictions[k], thr0.predictions[k]), k


def test_reuse_counters_and_registry_names(pipeline, stream):
    """A drifting-threshold run populates the cache.* counters and the
    registry bridge exports them under their canonical names."""
    svc = ServiceModel.modeled(pipeline.rep, pipeline.forest)
    st = replay(
        stream,
        lambda: StreamingRuntime(
            pipeline, capacity=1024, max_batch=16, execute=True,
            reuse=ReuseConfig(enabled=True, drift_threshold=0.5,
                              refresh_every=16)),
        stream.base_pps, svc, ring_capacity=512)
    m = st.metrics
    assert m.reuse_hits + m.refreshes > 0
    assert m.forced_reinfer == 0  # threshold > 0 never forces
    reg = m.to_registry()
    for name in ("cache.reuse_hits", "cache.refreshes",
                 "cache.forced_reinfer"):
        assert reg.counter(name) == getattr(
            m, name.removeprefix("cache.")), name


def test_fused_agg_entry_matches_unfused(ds):
    """The fused kernel's incremental (aggregate-block) inference entry
    agrees with the unfused reference on real table rows."""
    rep = FeatureRep(("dur", "s_load", "s_bytes_mean", "s_iat_mean",
                      "ack_cnt"), depth=DEPTH)
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="tree-fast", seed=0)
    unfused = build_pipeline(rep, forest, max_pkts=rep.depth,
                             use_kernel=False)
    fused = build_pipeline(rep, forest, max_pkts=rep.depth, fused=True)
    assert unfused.supports_agg and fused.supports_agg

    p = _synth_packets(n_flows=40, n_pkts=3000, seed=13)
    tbl = FlowTable(256, DEPTH, reuse=True, refresh_every=32, agg_buffer=256)
    _feed_block(tbl, p, 0, len(p["t"]))
    tbl.flush_agg()
    slots = np.flatnonzero(tbl.ctrl["state"] != 0)[:32]
    args = (tbl.agg[slots], tbl.proto[slots], tbl.s_port[slots],
            tbl.d_port[slots])
    a = np.asarray(unfused.predict_agg(*args))
    b = np.asarray(fused.predict_agg(*args))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

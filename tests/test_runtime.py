"""Streaming runtime invariants: flow table, bucketed dispatch, replay.

Covers the contracts DESIGN.md §6 promises: eviction/recycle correctness
under hash collision, streaming predictions bit-identical to the batch
`ServingPipeline`, replay bisection converging to a zero-drop rate, and
shape-bucketed dispatch compiling O(log max_batch) executables.
"""
import math

import numpy as np
import pytest

from repro.core.search_space import FeatureRep
from repro.serve.runtime import (
    FlowStatus,
    FlowTable,
    PacketStream,
    ServiceModel,
    StreamingRuntime,
    find_zero_loss_rate,
    next_bucket,
    replay,
)
from repro.traffic import extract_features, make_dataset
from repro.traffic.models import train_traffic_model
from repro.traffic.pipeline import build_pipeline

DEPTH = 8


@pytest.fixture(scope="module")
def ds():
    return make_dataset("app-class", n_flows=400, max_pkts=32, seed=5)


@pytest.fixture(scope="module")
def pipeline(ds):
    rep = FeatureRep(
        ("dur", "s_load", "s_bytes_mean", "s_iat_mean", "ack_cnt", "d_bytes_med"),
        depth=DEPTH,
    )
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="rf-fast", seed=0)
    return build_pipeline(rep, forest, max_pkts=rep.depth, use_kernel=False)


@pytest.fixture(scope="module")
def stream(ds):
    return PacketStream.from_dataset(ds, seed=0)


def _mk_runtime(pipeline, execute=True, **kw):
    kw.setdefault("capacity", 1024)
    kw.setdefault("max_batch", 64)
    kw.setdefault("min_bucket", 8)
    kw.setdefault("flush_timeout_s", 0.05)
    kw.setdefault("idle_timeout_s", 60.0)
    return StreamingRuntime(pipeline, execute=execute, **kw)


def _observe(table, key, t, fid=0, fin=False, size=100.0, direction=0):
    return table.observe(key, t, t, size, direction, 64.0, 1000.0, 0x10, 6.0,
                         40000.0, 443.0, fid, fin)


# ---------------------------------------------------------------------------
# flow table
# ---------------------------------------------------------------------------

def test_flow_accumulates_then_ready():
    ft = FlowTable(8, pkt_depth=4)
    for i in range(3):
        status, slot = _observe(ft, key=77, t=0.1 * i)
        assert status == FlowStatus.TRACKED
    status, slot = _observe(ft, key=77, t=0.3)
    assert status == FlowStatus.READY
    assert ft.ctrl["count"][slot] == 4
    np.testing.assert_allclose(ft.ts[slot], [0.0, 0.1, 0.2, 0.3], atol=1e-6)
    # packets past depth only touch the tracker
    status, _ = _observe(ft, key=77, t=0.4)
    assert status == FlowStatus.TRACKED
    assert ft.ctrl["count"][slot] == 4
    assert ft.ctrl["seen"][slot] == 5


def test_collision_chain_recycle_and_reuse():
    """Keys sharing a hash bucket must probe to distinct slots; deleting the
    first must not orphan the second (tombstone traversal)."""
    ft = FlowTable(8, pkt_depth=2)
    k1 = 3
    k2 = k1 + ft._n_buckets      # same bucket after masking
    k3 = k1 + 2 * ft._n_buckets
    _, s1 = _observe(ft, k1, 0.0, fid=1)
    _, s2 = _observe(ft, k2, 0.0, fid=2)
    assert s1 != s2
    assert ft._probe(k1)[0] == s1 and ft._probe(k2)[0] == s2
    ft.recycle(s1)
    # probing past the tombstone still finds k2
    assert ft._probe(k2)[0] == s2
    assert ft._probe(k1)[0] == -1
    # a new colliding key may reuse the tombstoned bucket; k2 stays reachable
    _, s3 = _observe(ft, k3, 0.0, fid=3)
    assert ft._probe(k3)[0] == s3
    assert ft._probe(k2)[0] == s2
    assert ft.n_active == 2


def test_overflow_drops_then_recycled_slot_admits():
    ft = FlowTable(3, pkt_depth=2)
    for i in range(3):
        _observe(ft, key=100 + i, t=0.0, fid=i)
    status, slot = _observe(ft, key=999, t=0.0, fid=9)
    assert status == FlowStatus.DROPPED and slot == -1
    assert ft.metrics.drops_table == 1
    # bidirectional FIN on a predicted flow frees its slot for the new flow
    _, s0 = _observe(ft, key=100, t=0.1, fid=0)
    ft.mark_predicted(np.array([s0]))
    assert ft.ctrl["state"][s0] == 3
    status, _ = _observe(ft, key=100, t=0.2, fid=0, fin=True, direction=0)
    assert status == FlowStatus.TRACKED  # half-close: flow NOT over yet
    status, _ = _observe(ft, key=100, t=0.3, fid=0, fin=True, direction=1)
    assert status == FlowStatus.CLOSED
    assert ft.metrics.slots_recycled == 1
    status, _ = _observe(ft, key=999, t=0.3, fid=9)
    assert status == FlowStatus.TRACKED
    assert ft.n_active == 3


def test_idle_eviction_flushes_partial_flows():
    ft = FlowTable(8, pkt_depth=4, idle_timeout_s=5.0)
    _observe(ft, key=1, t=0.0, fid=0)      # 1 pkt, never reaches depth
    _, s2 = _observe(ft, key=2, t=4.0, fid=1)
    late = ft.evict_idle(now=6.0)          # only flow 1 is idle > 5 s
    assert len(late) == 1
    assert ft.ctrl["flow_id"][late[0]] == 0
    assert ft.ctrl["state"][late[0]] == 2  # READY for a late flush
    assert ft.metrics.flows_evicted_idle == 1
    assert ft._probe(2)[0] == s2           # fresh flow untouched
    # idle PREDICTED flows are reclaimed silently
    ft.mark_predicted(np.array([s2]))
    ft.evict_idle(now=20.0)
    assert ft.n_active == 1                # only the late-flush READY remains


def test_half_close_does_not_end_flow():
    """FIN from one side + reverse-direction data (TCP half-close) must
    keep accumulating: only a bidirectional close ends the flow early."""
    ft = FlowTable(8, pkt_depth=6)
    _observe(ft, key=7, t=0.0, fid=0, direction=0)
    status, slot = _observe(ft, key=7, t=0.1, fid=0, fin=True, direction=0)
    assert status == FlowStatus.TRACKED          # half-closed, still open
    status, _ = _observe(ft, key=7, t=0.2, fid=0, direction=1)
    assert status == FlowStatus.TRACKED
    assert ft.ctrl["count"][slot] == 3           # reverse data accumulated
    status, _ = _observe(ft, key=7, t=0.3, fid=0, fin=True, direction=1)
    assert status == FlowStatus.READY_EOF        # now truly closed


def test_rebuild_during_recycle_drops_departing_slot():
    """Regression: an index rebuild triggered by the removal inside
    recycle() must not re-insert the slot being freed."""
    ft = FlowTable(8, pkt_depth=2)
    keys = [3 + i * 17 for i in range(6)]
    slots = [_observe(ft, k, 0.0, fid=i)[1] for i, k in enumerate(keys)]
    for s, k in zip(slots, keys):
        ft.recycle(s)
        assert ft._probe(k)[0] == -1
    assert ft.n_active == 0
    assert not (ft._buckets >= 0).any()          # no live entries remain
    # table is fully reusable afterwards
    for i, k in enumerate(keys):
        assert _observe(ft, k, 1.0, fid=i)[0] == FlowStatus.TRACKED
    assert ft.n_active == len(keys)


def test_tuple_hash_no_structural_collisions():
    """The lossy-overlap regression: related 5-tuples (ip bit 11 vs port
    bit 0, etc.) must hash differently, and keys must be stable."""
    from repro.serve.runtime import tuple_hash64

    a = tuple_hash64(0x0A000800, 0xC0A80001, 50000, 443, 6)
    b = tuple_hash64(0x0A000000, 0xC0A80001, 50001, 443, 6)
    assert a != b
    assert tuple_hash64(1, 2, 3, 4, 6) == tuple_hash64(1, 2, 3, 4, 6)
    # sequential source ips with varying ports (the PacketStream pattern)
    keys = {
        tuple_hash64(0x0A000000 + i, 0xC0A80000, 32768 + (i % 7), 443, 6)
        for i in range(5000)
    }
    assert len(keys) == 5000


def test_next_bucket_powers_of_two():
    assert next_bucket(1, 8, 256) == 8
    assert next_bucket(8, 8, 256) == 8
    assert next_bucket(9, 8, 256) == 16
    assert next_bucket(200, 8, 256) == 256
    assert next_bucket(300, 8, 256) == 256  # clamped


# ---------------------------------------------------------------------------
# dispatch + replay
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def low_rate_run(pipeline, stream):
    svc = ServiceModel.modeled(pipeline.rep, pipeline.forest)
    return replay(
        stream, lambda: _mk_runtime(pipeline, True), stream.base_pps, svc,
    )


def test_streaming_bit_identical_to_batch(ds, pipeline, low_rate_run):
    stats = low_rate_run
    assert stats.drops == 0
    assert len(stats.predictions) == ds.n_flows
    batch_preds = pipeline(ds.truncate(DEPTH))
    stream_preds = np.array([stats.predictions[i] for i in range(ds.n_flows)])
    assert (stream_preds == batch_preds).all()


def test_dispatch_uses_logarithmic_shape_buckets(low_rate_run):
    m = low_rate_run.metrics
    max_batch, min_bucket = 64, 8
    bound = int(math.log2(max_batch // min_bucket)) + 1
    assert 1 <= m.compile_count() <= bound
    for bucket, _ in m.shapes_seen:
        assert bucket & (bucket - 1) == 0  # power of two
        assert min_bucket <= bucket <= max_batch


def test_jit_cache_growth_bounded_by_buckets(pipeline, stream):
    """The real compile-count probe: replaying the full stream grows the
    extraction jit cache by at most one entry per shape bucket."""
    from repro.traffic.extraction import _extract

    svc = ServiceModel.modeled(pipeline.rep, pipeline.forest)
    before = _extract._cache_size()
    replay(stream, lambda: _mk_runtime(pipeline, True), stream.base_pps, svc)
    grown = _extract._cache_size() - before
    assert grown <= int(math.log2(64 // 8)) + 1


def test_occupancy_and_latency_metrics(low_rate_run):
    m = low_rate_run.metrics
    assert m.batches >= 1
    occ = m.occupancy_stats()
    assert 0 < occ["mean"] <= 1.0
    assert m.latency.n == m.flows_predicted
    assert 0 < low_rate_run.latency_p50_s <= low_rate_run.latency_p99_s


def test_timing_only_replay_matches_executing_replay(pipeline, stream, low_rate_run):
    """execute=False must reproduce the executing run's queueing exactly —
    that equivalence is what makes bisection probes sound."""
    svc = ServiceModel.modeled(pipeline.rep, pipeline.forest)
    dry = replay(stream, lambda: _mk_runtime(pipeline, False), stream.base_pps, svc)
    assert dry.drops == low_rate_run.drops
    assert dry.metrics.batches == low_rate_run.metrics.batches
    assert dry.latency_p99_s == pytest.approx(low_rate_run.latency_p99_s)
    assert dry.predictions == {}  # timing-only: no inference executed


def test_bisection_converges_to_zero_loss_edge(pipeline, stream):
    svc = ServiceModel.modeled(pipeline.rep, pipeline.forest)

    def make_rt(execute):
        return _mk_runtime(pipeline, execute, capacity=512, max_batch=64)

    rate, stats = find_zero_loss_rate(
        stream, make_rt, svc, lo_pps=stream.base_pps, iters=8,
    )
    assert stats.drops == 0                      # zero loss at reported rate
    assert rate >= stream.base_pps
    # strictly above the reported rate the pipeline drops: the bisection
    # actually found the saturation edge, not an arbitrary feasible point
    probe = replay(stream, lambda: make_rt(False), rate * 1.5, svc)
    assert probe.drops > 0
    # and well below it stays clean (monotone loss curve)
    probe_lo = replay(stream, lambda: make_rt(False), rate * 0.25, svc)
    assert probe_lo.drops == 0


def test_profiler_throughput_replayed_metric(ds):
    """The runtime is wired into the Profiler as a first-class cost metric."""
    from repro.traffic import TrafficProfiler

    prof = TrafficProfiler(
        ds, ("dur", "s_load", "s_bytes_mean", "s_iat_mean"),
        model="tree-fast", cost_metric="throughput_replayed",
        cost_mode="modeled", seed=0,
    )
    x = FeatureRep(("dur", "s_load", "s_bytes_mean"), DEPTH)
    r = prof(x)
    assert r.cost < 0          # negated Gbps for minimization
    assert 0 <= r.perf <= 1
    gbps, stats = prof.replayed_throughput_gbps(x, prof.perf_f1(x)[1],
                                                bisect_iters=6)
    assert gbps > 0 and stats.drops == 0


def test_profiler_replayed_metric_tiny_split():
    """The default ring capacity must clamp below the trace size even for
    tiny held-out splits (regression: floor of 64 tripped the ring guard)."""
    from repro.traffic import TrafficProfiler, make_dataset

    tiny = make_dataset("app-class", n_flows=60, max_pkts=16, seed=0)
    prof = TrafficProfiler(
        tiny, ("dur", "s_load", "s_bytes_mean"), model="tree-fast",
        cost_metric="throughput_replayed", cost_mode="modeled", seed=0,
    )
    r = prof(FeatureRep(("dur", "s_load"), 4))
    assert r.cost < 0


def test_flow_table_pressure_drops_new_flows(pipeline, stream):
    """A tiny table must shed flows (accounted as table drops), yet every
    admitted flow still gets exactly one prediction."""
    svc = ServiceModel.modeled(pipeline.rep, pipeline.forest)
    stats = replay(
        stream,
        lambda: _mk_runtime(pipeline, True, capacity=16, max_batch=16),
        stream.base_pps, svc,
    )
    assert stats.drops_table > 0
    assert 0 < len(stats.predictions) < stream.n_flows
    m = stats.metrics
    assert m.flows_predicted == len(stats.predictions)

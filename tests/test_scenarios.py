"""Adversarial traffic scenario generators (DESIGN.md §9.5).

Each named scenario must actually produce the pathology it claims —
otherwise the control-plane benchmarks measure nothing — while leaving
the "uniform" path bit-identical to the historical generator.
"""

import numpy as np
import pytest

from repro.serve.runtime import PacketStream
from repro.serve.runtime.shard import steer_flows
from repro.traffic.synth import (
    SCENARIOS,
    make_dataset,
    make_scenario_dataset,
    scenario_flow_starts,
)


def test_unknown_scenario_rejected():
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario_dataset("app-class", "tsunami", n_flows=10, max_pkts=8)
    with pytest.raises(ValueError, match="unknown scenario"):
        scenario_flow_starts(np.random.default_rng(0), 10, 1.0, "tsunami")


def test_uniform_scenario_is_bit_identical_to_plain_dataset():
    a = make_dataset("app-class", n_flows=80, max_pkts=16, seed=4)
    b = make_scenario_dataset("app-class", "uniform", n_flows=80,
                              max_pkts=16, seed=4)
    for f in ("ts", "size", "direction", "ttl", "winsize", "flags",
              "flow_len", "label"):
        assert (getattr(a, f) == getattr(b, f)).all()
    sa = PacketStream.from_dataset(a, seed=1)
    sb = PacketStream.from_dataset(b, seed=1, scenario="uniform")
    assert (sa.base_t == sb.base_t).all()


def test_flow_len_override_validation():
    with pytest.raises(ValueError, match="one entry per flow"):
        make_dataset("app-class", n_flows=10, max_pkts=16,
                     flow_len=np.array([5, 5]))
    ds = make_dataset("app-class", n_flows=10, max_pkts=16,
                      flow_len=np.full(10, 99))
    assert (ds.flow_len == 16).all()  # clipped to max_pkts
    # FIN placement follows the overridden lengths
    last = ds.flow_len - 1
    fin_col = ds.flags[np.arange(10), last, 7]
    assert fin_col.sum() >= 1


def test_zipf_scenario_concentrates_packet_mass():
    ds = make_scenario_dataset("app-class", "zipf", n_flows=120,
                               max_pkts=256, seed=3)
    stream = PacketStream.from_dataset(ds, seed=0)
    per_flow = np.bincount(stream.fid, minlength=ds.n_flows)
    share = np.sort(per_flow)[::-1]
    # elephants: the top decile of flows carries most of the packets
    assert share[:12].sum() / stream.n_events > 0.35
    # and the skew survives RSS steering: round-robin RETA leaves a
    # visibly hot shard (this is the pathology rebalancing fixes)
    shard = steer_flows(stream, 4)[stream.fid]
    counts = np.bincount(shard, minlength=4)
    assert counts.max() / counts.mean() > 1.3
    # duration equalization: elephants offer proportionally higher rate
    last = np.minimum(ds.flow_len, ds.max_pkts) - 1
    dur = ds.ts[np.arange(ds.n_flows), last]
    big = ds.flow_len >= 128
    small = ds.flow_len <= 8
    assert big.any() and small.any()
    assert np.median(dur[big]) < 4 * np.median(dur[small])


def test_burst_scenario_mmpp_arrivals():
    rng_u = np.random.default_rng(9)
    rng_b = np.random.default_rng(9)
    uni = scenario_flow_starts(rng_u, 4000, 1.0, "uniform")
    bur = scenario_flow_starts(rng_b, 4000, 1.0, "burst")
    gu = np.diff(uni)
    gb = np.diff(bur)
    # mean rate roughly preserved (bursts compress, lulls stretch)
    assert abs(gb.mean() - gu.mean()) / gu.mean() < 0.35
    # but the arrival process is far burstier: higher CoV of gaps
    cov_u = gu.std() / gu.mean()
    cov_b = gb.std() / gb.mean()
    assert cov_b > 1.3 * cov_u


def test_drift_scenario_class_mix_moves():
    ds = make_scenario_dataset("app-class", "drift", n_flows=600,
                               max_pkts=16, seed=2)
    K = len(ds.class_names)
    q = ds.n_flows // 4
    first = np.bincount(ds.label[:q], minlength=K) / q
    last = np.bincount(ds.label[-q:], minlength=K) / q
    # total-variation distance between early and late class mixes
    tv = 0.5 * np.abs(first - last).sum()
    assert tv > 0.4
    # content is a permutation of the plain dataset, not a relabeling
    plain = make_scenario_dataset("app-class", "uniform", n_flows=600,
                                  max_pkts=16, seed=2)
    assert sorted(ds.label.tolist()) == sorted(plain.label.tolist())


def test_scenarios_flow_through_packet_stream():
    for scenario in SCENARIOS:
        ds = make_scenario_dataset("app-class", scenario, n_flows=40,
                                   max_pkts=16, seed=0)
        st = PacketStream.from_dataset(ds, seed=0, scenario=scenario)
        assert st.n_flows == 40
        assert (np.diff(st.base_t) >= 0).all()

"""ServeSession: the unified attachment API and its deprecation shim.

PR 8's api_redesign satellite: every serving entry point takes one
``session=`` carrying obs / control / reopt / audit; the legacy per-call
keywords (``obs=``, ``control=``, ``audit=``, ``tracer=``) keep working
for one release behind a `DeprecationWarning` and produce *identical*
results. Also pins the `now_pkts` clock normalization: the control
surface never spells the packet clock ``now``.
"""
from __future__ import annotations

import inspect
import pathlib

import numpy as np
import pytest

from repro.core.search_space import FeatureRep
from repro.serve import (
    AuditLog,
    ControlConfig,
    ControlPlane,
    Observability,
    PacketStream,
    ServeSession,
    ServiceModel,
    ShardedRuntime,
    Tracer,
    controlled_replay,
    deploy,
    replay,
)
from repro.serve.obs.audit import AuditEvent
from repro.traffic import extract_features
from repro.traffic.models import train_traffic_model
from repro.traffic.pipeline import build_pipeline
from repro.traffic.synth import make_scenario_dataset

REP = FeatureRep(("dur", "s_load", "s_bytes_mean", "s_iat_mean", "ack_cnt"),
                 depth=8)


@pytest.fixture(scope="module")
def ds():
    return make_scenario_dataset("app-class", "uniform", n_flows=150,
                                 max_pkts=16, seed=7)


@pytest.fixture(scope="module")
def pipeline(ds):
    X = extract_features(ds, REP.features, REP.depth)
    forest, _ = train_traffic_model(X, ds.label, model="tree-fast", seed=0)
    return build_pipeline(REP, forest, max_pkts=REP.depth, use_kernel=False)


@pytest.fixture(scope="module")
def stream(ds):
    return PacketStream.from_dataset(ds, seed=0)


@pytest.fixture(scope="module")
def service():
    return ServiceModel(
        pkt_accum_ns=800.0, pkt_track_ns=200.0,
        bucket_ns={8: 3e4, 16: 4e4, 32: 6e4, 64: 1e5},
        gather_ns_per_flow=200.0, source="synthetic",
    )


def _fleet(pipeline):
    return ShardedRuntime(pipeline, n_shards=2, capacity=1024,
                          max_batch=32, execute=True)


# ---------------------------------------------------------------------------
# legacy keywords: warn, but behave identically
# ---------------------------------------------------------------------------


def test_replay_legacy_obs_equals_session(stream, pipeline, service):
    with pytest.warns(DeprecationWarning, match="obs="):
        legacy = replay(stream, lambda: _fleet(pipeline), 1e5, service,
                        obs=Observability())
    new = replay(stream, lambda: _fleet(pipeline), 1e5, service,
                 session=ServeSession(obs=Observability()))
    assert legacy.drops == new.drops
    assert legacy.predictions == new.predictions
    assert legacy.duration_s == new.duration_s


def test_controlled_replay_legacy_control_equals_session(
        stream, pipeline, service):
    cfg = ControlConfig(interval_pkts=256, rebalance=False)
    with pytest.warns(DeprecationWarning, match="control="):
        legacy = controlled_replay(stream, lambda: _fleet(pipeline), 1e5,
                                   service, control=cfg)
    new = controlled_replay(stream, lambda: _fleet(pipeline), 1e5, service,
                            session=ServeSession(control=cfg))
    assert legacy.predictions == new.predictions
    assert legacy.control["steps"] == new.control["steps"]


def test_session_plus_legacy_keyword_is_a_conflict(stream, pipeline, service):
    with pytest.raises(TypeError, match="not both"):
        replay(stream, lambda: _fleet(pipeline), 1e5, service,
               session=ServeSession(), obs=Observability())


def test_reopt_without_control_is_an_error(stream, pipeline, service):
    class _Stub:
        pass

    with pytest.raises(TypeError, match="control plane"):
        replay(stream, lambda: _fleet(pipeline), 1e5, service,
               session=ServeSession(reopt=_Stub()))


def test_deploy_legacy_audit_warns(pipeline, service, stream):
    from repro.serve.deploy import BundlePoint

    point = BundlePoint(rep=REP, cost=1.0, perf=0.9, fidelity="measured",
                        aux={}, compile_meta={"fused": False},
                        forest_doc=None, pipeline=pipeline)
    rt = _fleet(pipeline)
    log = AuditLog()
    with pytest.warns(DeprecationWarning, match="audit="):
        deploy(point, rt, 0.0, audit=log)
    assert [e.kind for e in log.events] == ["deploy"]


# ---------------------------------------------------------------------------
# resolution rules
# ---------------------------------------------------------------------------


def test_resolve_audit_precedence():
    explicit, bundled = AuditLog(), AuditLog()
    obs = Observability(audit=bundled)
    assert ServeSession(obs=obs, audit=explicit).resolve_audit() is explicit
    assert ServeSession(obs=obs).resolve_audit() is bundled
    assert ServeSession().resolve_audit() is None


def test_session_properties_thread_through_obs():
    tr = Tracer()
    obs = Observability(tracer=tr)
    s = ServeSession(obs=obs)
    assert s.tracer is tr
    assert s.drift is None
    assert ServeSession().tracer is None


def test_coerce_wraps_bare_tracer():
    tr = Tracer()
    with pytest.warns(DeprecationWarning, match="tracer="):
        s = ServeSession.coerce(tracer=tr)
    assert s.obs is not None and s.obs.tracer is tr


# ---------------------------------------------------------------------------
# now_pkts normalization
# ---------------------------------------------------------------------------


def test_audit_event_legacy_t_round_trip():
    ev = AuditEvent(seq=0, now_pkts=42.0, kind="deploy", rationale="r",
                    detail={})
    assert ev.t == 42.0                       # pre-rename alias
    assert AuditEvent.from_doc(ev.to_doc()).now_pkts == 42.0
    # documents written before the rename carried "t"
    old = {"seq": 1, "t": 7.0, "kind": "deploy", "rationale": "r",
           "detail": {}}
    assert AuditEvent.from_doc(old).now_pkts == 7.0


def test_control_surface_signatures_say_now_pkts():
    for fn in (ControlPlane.maybe_step, deploy, Tracer.instant,
               AuditLog.record):
        assert "now_pkts" in inspect.signature(fn).parameters, fn


def test_no_bare_now_keyword_anywhere_in_serve():
    """Lint: the packet clock is spelled now_pkts across the serving
    control surface. Worker-internal lane clocks assign ``now = ...``
    (with spaces); a literal ``now=`` substring would be a keyword
    argument regression."""
    root = pathlib.Path(__file__).resolve().parent.parent
    offenders = []
    for p in sorted((root / "src" / "repro" / "serve").rglob("*.py")):
        for i, line in enumerate(p.read_text().splitlines(), 1):
            if "now=" in line and "now_pkts" not in line:
                offenders.append(f"{p.name}:{i}: {line.strip()}")
    assert not offenders, "\n".join(offenders)


def test_predictions_identical_with_and_without_attachments(
        stream, pipeline, service):
    """Attachments observe; they never perturb the data path."""
    bare = replay(stream, lambda: _fleet(pipeline), 1e5, service)
    dressed = replay(
        stream, lambda: _fleet(pipeline), 1e5, service,
        session=ServeSession(
            obs=Observability(tracer=Tracer()),
            control=ControlConfig(interval_pkts=512, rebalance=False)))
    assert bare.predictions == dressed.predictions
    assert bare.drops == dressed.drops == 0
    for fid, pred in bare.predictions.items():
        assert isinstance(pred, (int, np.integer))

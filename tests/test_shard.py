"""Sharded runtime invariants (DESIGN.md §8).

The contracts the sharded layer promises:

- steering is *symmetric*: both directions of a 5-tuple land on the same
  shard (the RSS property that keeps a connection on one worker);
- sharding is *transparent*: predictions are bit-identical to a single
  worker fed the same packets — steering permutes workers, never output;
- the aggregate metrics view accounts exactly: per-shard counters sum to
  the fleet totals under overflow and idle eviction;
- `FlowTable` sizing knobs are constructor-injectable (no module
  constants to monkeypatch) so per-shard sizing is plain arguments.
"""

import numpy as np
import pytest

from repro.core.search_space import FeatureRep
from repro.serve.runtime import (
    FlowTable,
    PacketStream,
    ServiceModel,
    ShardedRuntime,
    StreamingRuntime,
    find_zero_loss_rate,
    replay,
    symmetric_tuple_hash64,
)
from repro.traffic import extract_features, make_dataset
from repro.traffic.models import train_traffic_model
from repro.traffic.pipeline import build_pipeline

DEPTH = 8


@pytest.fixture(scope="module")
def ds():
    return make_dataset("app-class", n_flows=300, max_pkts=32, seed=5)


@pytest.fixture(scope="module")
def pipeline(ds):
    rep = FeatureRep(
        ("dur", "s_load", "s_bytes_mean", "s_iat_mean", "ack_cnt"),
        depth=DEPTH,
    )
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="rf-fast", seed=0)
    return build_pipeline(rep, forest, max_pkts=rep.depth, use_kernel=False)


@pytest.fixture(scope="module")
def stream(ds):
    return PacketStream.from_dataset(ds, seed=0)


# ---------------------------------------------------------------------------
# steering
# ---------------------------------------------------------------------------


def test_symmetric_hash_direction_invariant():
    rng = np.random.default_rng(0)
    n = 4096
    s_ip = rng.integers(0, 2**32, n)
    d_ip = rng.integers(0, 2**32, n)
    s_port = rng.integers(0, 2**16, n)
    d_port = rng.integers(0, 2**16, n)
    proto = rng.choice([6, 17], n)
    fwd = symmetric_tuple_hash64(s_ip, d_ip, s_port, d_port, proto)
    rev = symmetric_tuple_hash64(d_ip, s_ip, d_port, s_port, proto)
    assert (fwd == rev).all()
    # still a hash: distinct tuples separate, zero is never produced
    assert len(np.unique(fwd)) == n
    assert (fwd != 0).all()


def test_scalar_and_array_hash_agree():
    one = symmetric_tuple_hash64(10, 20, 1000, 443, 6)
    many = symmetric_tuple_hash64([10], [20], [1000], [443], [6])
    assert int(one) == int(many[0])


def test_steering_both_directions_same_shard(pipeline):
    rt = ShardedRuntime(pipeline, n_shards=4, execute=False)
    rng = np.random.default_rng(1)
    n = 2048
    s_ip = rng.integers(0, 2**32, n)
    d_ip = rng.integers(0, 2**32, n)
    s_port = rng.integers(0, 2**16, n)
    d_port = rng.integers(0, 2**16, n)
    proto = np.full(n, 6)
    fwd = rt.steer(s_ip, d_ip, s_port, d_port, proto)
    rev = rt.steer(d_ip, s_ip, d_port, s_port, proto)
    assert (fwd == rev).all()
    assert fwd.min() >= 0 and fwd.max() < 4
    # the indirection spread is roughly even over random tuples
    counts = np.bincount(fwd, minlength=4)
    assert counts.max() / counts.mean() < 1.3


def test_capacity_budget_split_per_shard(pipeline):
    rt = ShardedRuntime(pipeline, n_shards=4, capacity=2048, execute=False)
    assert rt.capacity_per_shard == 512
    assert all(s.table.capacity == 512 for s in rt.shards)
    explicit = ShardedRuntime(
        pipeline, n_shards=4, capacity=2048, capacity_per_shard=128, execute=False
    )
    assert all(s.table.capacity == 128 for s in explicit.shards)


# ---------------------------------------------------------------------------
# transparency: sharded == single, bit for bit
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def single_run(pipeline, stream):
    svc = ServiceModel.modeled(pipeline.rep, pipeline.forest)
    return replay(
        stream,
        lambda: StreamingRuntime(pipeline, capacity=1024, max_batch=64),
        stream.base_pps,
        svc,
    )


@pytest.fixture(scope="module")
def sharded_run(pipeline, stream):
    svc = ServiceModel.modeled(pipeline.rep, pipeline.forest)
    return replay(
        stream,
        lambda: ShardedRuntime(pipeline, n_shards=3, capacity=1024, max_batch=64),
        stream.base_pps,
        svc,
    )


def test_sharded_predictions_bitwise_equal_single(ds, single_run, sharded_run):
    assert single_run.drops == 0 and sharded_run.drops == 0
    assert len(sharded_run.predictions) == ds.n_flows
    assert sharded_run.predictions.keys() == single_run.predictions.keys()
    for fid, pred in single_run.predictions.items():
        assert sharded_run.predictions[fid] == pred


def test_sharded_predictions_bitwise_equal_batch(ds, pipeline, sharded_run):
    batch_preds = pipeline(ds.truncate(DEPTH))
    stream_preds = np.array([sharded_run.predictions[i] for i in range(ds.n_flows)])
    assert (stream_preds == batch_preds).all()


def test_live_ingest_facade_matches_replay(pipeline, stream, single_run):
    """Feeding interleaved delivery-order blocks through the facade's own
    steering reproduces the single worker's predictions exactly."""
    rt = ShardedRuntime(pipeline, n_shards=3, capacity=1024, max_batch=64)
    shard_of_pkt = rt.steer_stream(stream)[stream.fid]
    fid = stream.fid
    E = stream.n_events
    for lo in range(0, E, 512):
        hi = min(lo + 512, E)
        sl = slice(lo, hi)
        rt.ingest_packets(
            stream.key[fid[sl]],
            stream.base_t[sl],
            stream.rel_ts32[sl],
            stream.size[sl],
            stream.direction[sl],
            stream.ttl[sl],
            stream.winsize[sl],
            stream.flags_byte[sl],
            stream.proto[fid[sl]],
            stream.s_port[fid[sl]],
            stream.d_port[fid[sl]],
            fid[sl],
            stream.fin[sl],
            shard=shard_of_pkt[sl],
        )
    rt.drain(float(stream.base_t[-1]) + 1.0)
    assert rt.results.keys() == single_run.predictions.keys()
    for fid_, pred in single_run.predictions.items():
        assert rt.results[fid_] == pred


def test_profiler_sharded_metric_tiny_split():
    """The per-shard ring division must not undo the trace-size clamp
    (regression: the 64 floor re-applied after clamping tripped the
    zero-loss ring guard on tiny held-out splits)."""
    from repro.traffic import TrafficProfiler, make_dataset

    tiny = make_dataset("app-class", n_flows=60, max_pkts=16, seed=0)
    prof = TrafficProfiler(
        tiny,
        ("dur", "s_load", "s_bytes_mean"),
        model="tree-fast",
        cost_metric="throughput_replayed_sharded",
        cost_mode="modeled",
        n_shards=2,
        seed=0,
    )
    r = prof(FeatureRep(("dur", "s_load"), 4))
    assert r.cost < 0


def test_sharded_zero_loss_scales(pipeline, stream):
    """4 steered workers must beat one worker's zero-loss rate by well
    more than the load-imbalance factor alone would forgive."""
    svc = ServiceModel.modeled(pipeline.rep, pipeline.forest)
    ring = max(64, stream.n_events // 8)

    def mk1(execute):
        return StreamingRuntime(pipeline, capacity=1024, max_batch=64, execute=execute)

    def mk4(execute):
        return ShardedRuntime(
            pipeline, n_shards=4, capacity=1024, max_batch=64, execute=execute
        )

    r1, s1 = find_zero_loss_rate(stream, mk1, svc, iters=6, ring_capacity=ring)
    r4, s4 = find_zero_loss_rate(stream, mk4, svc, iters=6, ring_capacity=ring)
    assert s1.drops == 0 and s4.drops == 0
    assert s4.n_shards == 4
    assert r4 > r1
    assert s4.load_imbalance >= 1.0
    assert len(s4.per_shard) == 4


# ---------------------------------------------------------------------------
# aggregate metrics accounting
# ---------------------------------------------------------------------------


def test_aggregate_metrics_account_overflow_and_eviction(pipeline, stream):
    """Tiny per-shard tables shed flows; the aggregate view must equal the
    per-shard sum exactly, and every admitted flow still predicts once."""
    svc = ServiceModel.modeled(pipeline.rep, pipeline.forest)
    stats = replay(
        stream,
        lambda: ShardedRuntime(
            pipeline, n_shards=3, capacity_per_shard=8, max_batch=16
        ),
        stream.base_pps,
        svc,
    )
    m = stats.metrics  # merged RuntimeMetrics
    assert stats.drops_table > 0
    per = stats.per_shard
    assert sum(p["drops_table"] for p in per) == m.drops_table
    assert sum(p["drops_ring"] for p in per) == m.drops_ring
    assert sum(p["pkts_total"] for p in per) == m.pkts_total
    assert sum(p["flows_predicted"] for p in per) == m.flows_predicted
    assert m.flows_predicted == len(stats.predictions)
    assert 0 < len(stats.predictions) < stream.n_flows
    assert stats.load_imbalance >= 1.0
    # latency samples merge across shards: one sample per predicted flow
    assert m.latency.n == m.flows_predicted


def test_aggregate_metrics_view_sums_shards(pipeline):
    rt = ShardedRuntime(pipeline, n_shards=3, execute=False)
    for i, s in enumerate(rt.shards):
        s.metrics.drops_ring = 10 * (i + 1)
        s.metrics.drops_table = i
        s.metrics.flows_evicted_idle = 2
        s.metrics.pkts_total = 100
    agg = rt.metrics
    assert agg.drops_ring == 60
    assert agg.drops_table == 3
    assert agg.drops == 63
    assert agg.flows_evicted_idle == 6
    assert agg.load_imbalance() == 1.0
    summ = agg.summary()
    assert summ["n_shards"] == 3
    assert len(summ["per_shard"]) == 3
    assert summ["aggregate"]["pkts_total"] == 300


# ---------------------------------------------------------------------------
# constructor-injectable flow-table knobs
# ---------------------------------------------------------------------------


def test_flow_table_load_factor_injectable():
    dense = FlowTable(64, pkt_depth=4, load_factor=0.6)
    sparse = FlowTable(64, pkt_depth=4, load_factor=0.25)
    assert dense._n_buckets == 128
    assert sparse._n_buckets == 256
    # default keeps the historical load <= 0.5 sizing
    assert FlowTable(64, pkt_depth=4)._n_buckets == 128
    with pytest.raises(ValueError):
        FlowTable(64, pkt_depth=4, load_factor=0.0)
    # a full table must always keep an EMPTY bucket or probes can spin
    with pytest.raises(ValueError):
        FlowTable(64, pkt_depth=4, load_factor=1.0)
    with pytest.raises(ValueError):
        FlowTable(64, pkt_depth=4, load_factor=0.8, rebuild_tombstone_frac=0.25)


def test_flow_table_rebuild_threshold_injectable():
    ft = FlowTable(32, pkt_depth=2, rebuild_tombstone_frac=0.0)
    slots = []
    for i in range(4):
        _, slot = ft.observe(
            100 + i, 0.0, 0.0, 1.0, 0, 64.0, 0.0, 0, 6.0, 1.0, 2.0, i, False
        )
        slots.append(slot)
    ft.recycle(slots[0])
    # frac 0.0: the very first tombstone triggers a rebuild, leaving none
    assert ft._tombstones == 0
    # frac 0.49 on a 64-bucket table: rebuild only past 31 tombstones
    lazy = FlowTable(32, pkt_depth=2, rebuild_tombstone_frac=0.49)
    for i in range(4):
        _, slot = lazy.observe(
            100 + i, 0.0, 0.0, 1.0, 0, 64.0, 0.0, 0, 6.0, 1.0, 2.0, i, False
        )
        lazy.recycle(slot)
    assert lazy._tombstones == 4


def test_sharded_runtime_threads_table_knobs(pipeline):
    rt = ShardedRuntime(
        pipeline,
        n_shards=2,
        capacity=64,
        execute=False,
        load_factor=0.25,
        rebuild_tombstone_frac=0.5,
    )
    for s in rt.shards:
        assert s.table.capacity == 32
        assert s.table._n_buckets == 128  # 32 / 0.25
        assert s.table.rebuild_tombstone_frac == 0.5

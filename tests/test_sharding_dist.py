"""Distribution layer on 8 fake host devices: specs, MoE EP, train parity."""
import os

# must be set before jax initializes — pytest runs this module first only if
# no other test already initialized jax; keep the device count modest and
# compatible with other test modules by using a subprocess guard instead.
import subprocess
import sys


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_local_mesh
from repro.launch.specs import batch_pspecs, build_cell, cache_pspecs
from repro.models import init_params, loss_fn
from repro.models.config import ShapeSpec
from repro.parallel import parallel_ctx, param_pspecs
from repro.parallel.sharding import default_rules
from repro.train import AdamW, init_state, make_train_step

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
rules = default_rules(mesh)

# ---- 1. param specs cover every leaf and divide shapes
cfg = configs.get_reduced("qwen3-8b")
with parallel_ctx(mesh, rules) as ctx:
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_pspecs(params, ctx)
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    flat_s = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for (path, leaf), spec in zip(flat_p, flat_s):
        for ax, dim in zip(tuple(spec) + (None,) * leaf.ndim, leaf.shape):
            if ax is None: continue
            size = int(np.prod([mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))]))
            assert dim % size == 0, (path, leaf.shape, spec)
print("param specs OK")

# ---- 2. distributed train step == single-device train step (dense)
cfg32 = dataclasses.replace(cfg, dtype="float32")
opt = AdamW(lr=1e-3, zero1=True)
step = make_train_step(cfg32, opt)
state = init_state(cfg32, jax.random.PRNGKey(0), opt)
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, cfg32.vocab_size, (4, 16)), jnp.int32)
batch = {"tokens": toks, "targets": toks}

# single device
s1, m1 = jax.jit(step)(jax.tree_util.tree_map(jnp.copy, state), batch)

# distributed
with parallel_ctx(mesh, rules) as ctx:
    def wrapped(s, b):
        with parallel_ctx(mesh, rules):
            return step(s, b)
    s2, m2 = jax.jit(wrapped)(jax.tree_util.tree_map(jnp.copy, state), batch)

d_loss = abs(float(m1["loss"]) - float(m2["loss"]))
assert d_loss < 1e-4, d_loss
p1 = jax.tree_util.tree_leaves(s1["params"])
p2 = jax.tree_util.tree_leaves(s2["params"])
err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(p1, p2))
assert err < 1e-4, err
print("distributed == single-device train step OK (err %.2e)" % err)

# ---- 3. MoE arch trains under the mesh with sharded experts (EP path)
cfgm = dataclasses.replace(configs.get_reduced("kimi-k2-1t-a32b"),
                           dtype="float32", n_expert_slots=8)
stepm = make_train_step(cfgm, opt)
statem = init_state(cfgm, jax.random.PRNGKey(1), opt)
batchm = {"tokens": toks % cfgm.vocab_size, "targets": toks % cfgm.vocab_size}
with parallel_ctx(mesh, rules):
    def wrappedm(s, b):
        with parallel_ctx(mesh, rules):
            return stepm(s, b)
    sm, mm = jax.jit(wrappedm)(statem, batchm)
assert np.isfinite(float(mm["loss"]))
print("MoE EP train step OK loss=%.4f" % float(mm["loss"]))

# ---- 4. build_cell lowers + compiles decode on the toy mesh
cell = build_cell(configs.get_reduced("qwen3-8b"),
                  ShapeSpec("t", 64, 8, "decode"), mesh)
compiled = cell.fn.lower(*cell.abstract).compile()
assert compiled is not None
print("decode cell compile OK")
print("ALL_OK")
"""


def test_distribution_layer_on_fake_mesh():
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "ALL_OK" in r.stdout, r.stdout + "\n" + r.stderr

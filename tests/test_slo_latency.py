"""SLO-native latency observability (DESIGN.md §14): bounded-relative-
error quantile sketches with bit-identical permutation merges, per-stage
latency decomposition whose components sum to the end-to-end total,
windowed burn-rate SLO verdicts audited by the control plane, and a
fleet exporter whose Prometheus/JSONL output validates."""
import json
import math

import numpy as np
import pytest

from repro.core.search_space import FeatureRep
from repro.serve import ServeSession
from repro.serve.control import ControlConfig
from repro.serve.control.replay import controlled_replay
from repro.serve.obs import (
    COMPONENTS,
    LatencyConfig,
    LatencyRecorder,
    LatencySketch,
    MetricsExporter,
    MetricsRegistry,
    Observability,
    SLOConfig,
    SLOTracker,
    check_prometheus,
    render_prometheus,
)
from repro.serve.runtime import (
    LatencyHistogram,
    PacketStream,
    ServiceModel,
    ShardedRuntime,
    replay,
)
from repro.traffic import extract_features
from repro.traffic.models import train_traffic_model
from repro.traffic.pipeline import build_pipeline
from repro.traffic.synth import make_scenario_dataset

ALPHA = 0.01


@pytest.fixture(scope="module")
def ds():
    return make_scenario_dataset("app-class", "zipf", n_flows=120,
                                 max_pkts=256, seed=3)


@pytest.fixture(scope="module")
def pipeline(ds):
    rep = FeatureRep(
        ("dur", "s_load", "s_bytes_mean", "s_iat_mean", "ack_cnt"), depth=8)
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="tree-fast", seed=0)
    return build_pipeline(rep, forest, max_pkts=rep.depth, use_kernel=False)


@pytest.fixture(scope="module")
def stream(ds):
    return PacketStream.from_dataset(ds, seed=0)


@pytest.fixture(scope="module")
def service():
    return ServiceModel(
        pkt_accum_ns=800.0, pkt_track_ns=200.0,
        bucket_ns={8: 3e4, 16: 4e4, 32: 6e4, 64: 1e5},
        gather_ns_per_flow=200.0, source="synthetic",
    )


def fleet(pipeline, n_shards=4, execute=False, **kw):
    return ShardedRuntime(pipeline, n_shards=n_shards, capacity=2048,
                          max_batch=64, execute=execute, **kw)


def _exact_percentile(x, q):
    """The rank statistic the sketch bound is stated against."""
    s = np.sort(np.asarray(x, np.float64))
    return float(s[min(max(int(math.ceil(q / 100.0 * len(s))), 1),
                       len(s)) - 1])


def _dists():
    rng = np.random.default_rng(7)
    uniform = rng.uniform(1e-5, 1e-2, 50_000)
    zipf = np.clip(rng.zipf(1.7, 50_000) * 1e-6, None, 1.0)
    lognormal = np.exp(rng.normal(math.log(2e-4), 1.2, 50_000))
    return {"uniform": uniform, "zipf": zipf, "lognormal": lognormal}


# ---------------------------------------------------------------------------
# sketch: accuracy bound, merge laws, edges
# ---------------------------------------------------------------------------


def test_sketch_relative_error_bound():
    """Every reported percentile is within alpha of the exact rank
    statistic, under skews from flat to heavy-tailed."""
    for name, x in _dists().items():
        sk = LatencySketch(alpha=ALPHA)
        sk.record_many(x)
        for q in (1.0, 25.0, 50.0, 90.0, 99.0, 99.9):
            exact = _exact_percentile(x, q)
            got = sk.percentile(q)
            rel = abs(got - exact) / exact
            assert rel <= ALPHA * 1.0001, (name, q, rel)
        # the extremes obey the same bound (clamped to the exact
        # running min/max) and the integer-ns mean is exact
        assert sk.n == len(x)
        assert sk.percentile(0) == pytest.approx(float(x.min()), rel=ALPHA)
        assert sk.percentile(100) == pytest.approx(float(x.max()), rel=ALPHA)
        assert sk.mean_s == pytest.approx(float(x.mean()), rel=1e-6)


def test_sketch_merge_bit_identical_under_permutation():
    """Shard merges commute bit-for-bit: any merge order of any split
    produces the same frozen doc as one sketch that saw everything."""
    x = _dists()["lognormal"]
    parts = np.array_split(x, 7)
    whole = LatencySketch(alpha=ALPHA)
    whole.record_many(x)

    rng = np.random.default_rng(0)
    for _ in range(4):
        order = rng.permutation(len(parts))
        merged = LatencySketch(alpha=ALPHA)
        for i in order:
            shard = LatencySketch(alpha=ALPHA)
            shard.record_many(parts[i])
            merged.merge_from(shard)
        assert merged.to_doc() == whole.to_doc()


def test_sketch_edges_and_clamps():
    sk = LatencySketch(alpha=ALPHA, lo_s=1e-9, hi_s=1e2)
    assert sk.percentile(50) == 0.0 and sk.n == 0
    assert sk.summary()["p99_s"] == 0.0

    one = LatencySketch(alpha=ALPHA)
    one.record(3.5e-4)
    for q in (0.0, 50.0, 100.0):
        assert one.percentile(q) == pytest.approx(3.5e-4, rel=ALPHA)

    # under/overflow report the exact running min/max, not bucket values
    ends = LatencySketch(alpha=ALPHA, lo_s=1e-6, hi_s=1e-3)
    ends.record_many(np.array([1e-8, 5e-1]))
    assert ends.percentile(1) == pytest.approx(1e-8)
    assert ends.percentile(99.9) == pytest.approx(5e-1)


def test_sketch_scalar_record_matches_vector_path():
    """`record(v, count=k)` (the per-batch shared-value path) lands in
    exactly the same bucket state as k vectorized records."""
    vals = [2.3e-5, 8e-4, 1.7e-2, 0.5]
    a = LatencySketch(alpha=ALPHA)
    b = LatencySketch(alpha=ALPHA)
    for v in vals:
        a.record(v, count=9)
        b.record_many(np.full(9, v))
    assert a.to_doc() == b.to_doc()


def test_sketch_layout_mismatch_raises():
    a = LatencySketch(alpha=0.01)
    b = LatencySketch(alpha=0.02)
    with pytest.raises(ValueError, match="layout mismatch"):
        a.merge_from(b)
    with pytest.raises(ValueError):
        LatencySketch(alpha=1.5)
    with pytest.raises(ValueError):
        LatencySketch(lo_s=1.0, hi_s=0.1)


def test_sketch_doc_roundtrip():
    x = _dists()["uniform"]
    sk = LatencySketch(alpha=ALPHA)
    sk.record_many(x)
    doc = sk.to_doc()
    json.dumps(doc)                       # artifact contract
    back = LatencySketch.from_doc(doc)
    assert back.to_doc() == doc
    assert back.percentile(99) == sk.percentile(99)


# ---------------------------------------------------------------------------
# histogram past the reservoir cap (satellite regression)
# ---------------------------------------------------------------------------


def test_histogram_percentile_past_cap():
    """Beyond `max_samples` the reservoir is a biased subsample; with a
    sketch attached the histogram reports the alpha-bounded value, and
    the plain bucket fallback stays within its documented (coarse)
    bucket-width bound."""
    x = _dists()["lognormal"]
    exact99 = _exact_percentile(x, 99)

    sketched = LatencyHistogram(max_samples=64)
    sketched.attach_sketch(alpha=ALPHA)
    sketched.record_many(x)
    assert abs(sketched.percentile(99) - exact99) / exact99 <= ALPHA * 1.0001

    plain = LatencyHistogram(max_samples=64)
    plain.record_many(x)
    # documented bucket-interpolation bound: one log-bucket of relative
    # width (~33% at the default 8 buckets per decade)
    bucket_bound = float(plain.edges[1] / plain.edges[0]) - 1.0
    assert abs(plain.percentile(99) - exact99) / exact99 <= bucket_bound

    # below the cap the reservoir short-circuits the sketch: percentiles
    # stay the exact interpolated statistic of the raw samples
    small = LatencyHistogram(max_samples=8192)
    small.attach_sketch(alpha=ALPHA)
    y = x[:1000]
    small.record_many(y)
    assert small.percentile(99) == pytest.approx(float(np.percentile(y, 99)))


# ---------------------------------------------------------------------------
# recorder: replayed per-stage decomposition
# ---------------------------------------------------------------------------


def _replayed_fleet(pipeline, stream, service, obs):
    created = []

    def mk():
        rt = fleet(pipeline)
        created.append(rt)
        return rt

    stats = replay(stream, mk, 2e5, service,
                   session=ServeSession(obs=obs))
    return stats, created[-1]


def test_replay_decomposition_identity(pipeline, stream, service):
    """queue_wait + batch + service == total, per replayed run, on the
    integer-ns sums; the p99 decomposition is consistent with the
    end-to-end percentile the replay already reports."""
    obs = Observability(latency=LatencyConfig(alpha=ALPHA))
    stats, rt = _replayed_fleet(pipeline, stream, service, obs)

    recs = [s.metrics.latency_components for s in rt.shards]
    assert all(r is not None for r in recs)
    merged = recs[0].fresh()
    for r in recs:
        merged.merge_from(r)

    # every component saw every charged flow exactly once
    ns = {c: merged.sketches[c].n for c in COMPONENTS}
    assert len(set(ns.values())) == 1 and ns["total"] > 0
    # per-shard: the linked sketch tracks the histogram sample count
    # exactly (the past-cap upgrade path requires this)
    for s in rt.shards:
        assert s.metrics.latency_components.sketches["total"].n \
            == s.metrics.latency.n

    # integer-ns sum identity (each charge rounds each component once:
    # tolerate 2ns per charged batch)
    parts_sum = sum(merged.sketches[c].sum_s
                    for c in ("queue_wait", "batch", "service"))
    tol = 2e-9 * ns["total"] + 1e-9
    assert abs(parts_sum - merged.sketches["total"].sum_s) <= tol

    # the sketch total agrees with the replay's own p99 within alpha
    # (sample count is under the reservoir cap here, so that one's exact)
    p99 = merged.sketches["total"].percentile(99)
    assert abs(p99 - stats.latency_p99_s) / stats.latency_p99_s <= ALPHA * 1.01
    # and the stage p99s bound the tail (Bonferroni: at most 3% of
    # samples exceed *any* component p99, so the total's p97 is bounded
    # by the stage-p99 sum; allow the sketch's alpha per component)
    stage_p99 = sum(merged.sketches[c].percentile(99)
                    for c in ("queue_wait", "batch", "service"))
    assert merged.sketches["total"].percentile(97) <= \
        stage_p99 * (1.0 + 4 * ALPHA)


def test_fleet_registry_sketch_merge_permutation(pipeline, stream, service):
    """The registry carries the sketches through the same order-free
    merge law as counters: forward and reversed shard orders snapshot
    bit-identically, including the new "sketches" section."""
    obs = Observability(latency=LatencyConfig(alpha=ALPHA))
    _, rt = _replayed_fleet(pipeline, stream, service, obs)
    parts = [s.metrics.to_registry() for s in rt.shards]
    fwd = MetricsRegistry.merge(parts).snapshot()
    rev = MetricsRegistry.merge(parts[::-1]).snapshot()
    fs, rs = fwd.pop("samples"), rev.pop("samples")
    assert fwd == rev
    assert set(fwd["sketches"]) == {f"latency.{c}" for c in COMPONENTS}
    assert {k: sorted(v) for k, v in fs.items()} == \
        {k: sorted(v) for k, v in rs.items()}

    # a merged registry reconstitutes a recorder without aliasing
    merged = MetricsRegistry.merge(parts)
    rec = LatencyRecorder.from_registry(merged)
    assert rec.n == sum(s.metrics.latency_components.n for s in rt.shards)


def test_scale_out_mints_fresh_recorder(pipeline, stream, service):
    """Late workers added after attach still decompose latency: the
    fleet carries the recorder config onto minted shards."""
    obs = Observability(latency=LatencyConfig(alpha=ALPHA))
    rt = fleet(pipeline, n_shards=2)
    obs.attach(rt)
    rt.add_worker()
    assert rt.shards[-1].metrics.latency_components is not None
    assert rt.shards[-1].metrics.latency_components.n == 0


# ---------------------------------------------------------------------------
# SLO tracker: windows, burn rates, merge
# ---------------------------------------------------------------------------


def test_slo_attainment_and_burn_verdicts():
    cfg = SLOConfig(target_s=1e-3, objective=0.9, window_s=1.0,
                    slow_windows=4)
    tr = SLOTracker(cfg)
    # window 0: all good -> no breach, burn 0
    tr.note(0.5, np.full(50, 1e-4))
    v = tr.check(0.5)
    assert not v.breached and v.burn_fast == 0.0 and v.attainment_fast == 1.0
    # window 1: 50% violations -> burn 5x the 10% budget, rising edge
    tr.note(1.5, np.r_[np.full(25, 1e-4), np.full(25, 5e-3)])
    v = tr.check(1.5)
    assert v.breached and v.new_breach
    assert v.attainment_fast == pytest.approx(0.5)
    assert v.burn_fast == pytest.approx(5.0)
    assert v.samples_fast == 50 and v.samples_slow == 100
    # still breached: no second rising edge
    v2 = tr.check(1.9)
    assert v2.breached and not v2.new_breach
    assert tr.breaches == 1
    # windows later, the slow burn has faded -> recovered
    v3 = tr.check(9.0)
    assert not v3.breached
    assert tr.attainment == pytest.approx(1.0 - 25 / 100)
    json.dumps(tr.signal())


def test_slo_empty_window_never_breaches():
    tr = SLOTracker(SLOConfig(target_s=1e-3, objective=0.99, window_s=1.0))
    v = tr.check(100.0)
    assert not v.breached and v.samples_fast == 0
    assert v.attainment_fast == 1.0 and tr.attainment == 1.0


def test_slo_merge_permutation_and_mismatch():
    cfg = SLOConfig(target_s=1e-3, objective=0.95, window_s=0.5)
    rng = np.random.default_rng(11)
    shards = []
    for s in range(5):
        tr = SLOTracker(cfg)
        for _ in range(20):
            tr.note(float(rng.uniform(0, 4)),
                    rng.choice([1e-4, 5e-3], size=8))
        shards.append(tr)
    fwd, rev = SLOTracker(cfg), SLOTracker(cfg)
    for tr in shards:
        fwd.merge_from(tr)
    for tr in shards[::-1]:
        rev.merge_from(tr)
    assert fwd.signal() == rev.signal()
    assert fwd.samples == sum(t.samples for t in shards)
    with pytest.raises(ValueError, match="config mismatch"):
        fwd.merge_from(SLOTracker(SLOConfig(target_s=2e-3)))
    with pytest.raises(ValueError):
        SLOConfig(target_s=1e-3, objective=1.5)


# ---------------------------------------------------------------------------
# control plane: audited breaches + exporter cadence
# ---------------------------------------------------------------------------


def _controlled(pipeline, stream, service, target_s, jsonl_path):
    slo = SLOTracker(SLOConfig(target_s=target_s, objective=0.99,
                               window_s=0.02, slow_windows=4))
    obs = Observability(latency=LatencyConfig(alpha=ALPHA), slo=slo,
                        exporter=MetricsExporter(jsonl_path=jsonl_path))
    session = ServeSession(
        obs=obs,
        control=ControlConfig(interval_pkts=512, imbalance_trigger=1.04),
    )
    stats = controlled_replay(stream, lambda: fleet(pipeline), 2e5, service,
                              session=session)
    return stats, obs


def test_slo_breach_audited_once_per_episode(pipeline, stream, service,
                                             tmp_path):
    """An unattainable target breaches and lands in the audit log as
    kind "slo" — on the rising edge, not once per control step."""
    path = tmp_path / "ts.jsonl"
    stats, obs = _controlled(pipeline, stream, service, 1e-9, str(path))
    events = obs.audit.of_kind("slo")
    assert len(events) >= 1
    assert obs.slo.breaches == len(events)
    assert obs.slo.checks > len(events)       # edge-triggered, not per-step
    ev = events[0]
    assert ev.detail["breached"] and ev.detail["burn_fast"] >= 1.0
    assert "error budget" in ev.rationale
    assert obs.slo.attainment < 0.5

    # exporter: one JSONL line per executed control step, each a full
    # frozen record carrying the registry and the SLO signal
    lines = [json.loads(s) for s in path.read_text().splitlines()]
    assert len(lines) == obs.exporter.steps >= 1
    assert [d["step"] for d in lines] == list(range(len(lines)))
    last = lines[-1]
    assert last["slo"]["breached"] and last["slo"]["samples"] > 0
    assert "latency.total" in last["registry"]["sketches"]
    assert last["registry"]["counters"]["slo.samples"] == \
        last["slo"]["samples"]

    # Prometheus render of the bound fleet view validates
    text = obs.exporter.prometheus()
    assert check_prometheus(text) == []
    assert 'cato_latency_total{quantile="0.99"}' in text
    assert "cato_slo_breaches" in text


def test_slo_met_is_silent(pipeline, stream, service, tmp_path):
    """A comfortably met objective produces zero "slo" audit events and
    an attainment of exactly 1."""
    stats, obs = _controlled(pipeline, stream, service, 10.0,
                             str(tmp_path / "ts.jsonl"))
    assert obs.audit.of_kind("slo") == []
    assert obs.slo.breaches == 0 and obs.slo.violations == 0
    assert obs.slo.attainment == 1.0
    assert obs.slo.samples == obs.slo.samples  # lifetime counters exist
    # the verdict gauges are still published every step (value 0/1.0)
    snap = obs.exporter.last["registry"]
    assert snap["gauges"]["slo.breached"]["value"] == 0.0


# ---------------------------------------------------------------------------
# exporter: render + checker
# ---------------------------------------------------------------------------


def test_render_prometheus_families_and_checker():
    reg = MetricsRegistry()
    reg.inc("ingest.pkts_total", 100)
    reg.inc("shard0.ingest.pkts_total", 60)
    reg.inc("shard1.ingest.pkts_total", 40)
    reg.set_gauge("flow_table.load_factor", 0.5, reduce="max")
    reg.union("dispatch.shapes_seen", [(8, 5)])
    reg.extend_samples("dispatch.batch_occupancy", [3, 9])
    h = LatencyHistogram()
    h.record_many(np.array([1e-3, 2e-3, 4e-3]))
    reg.attach_hist("dispatch.latency", h)
    sk = LatencySketch()
    sk.record_many(np.array([1e-4, 2e-4]))
    reg.attach_sketch("latency.total", sk)

    text = render_prometheus(reg)
    assert check_prometheus(text) == []
    lines = text.splitlines()
    # shard columns land as labels of one family, not mangled names
    assert 'cato_ingest_pkts_total{shard="0"} 60' in lines
    assert "cato_ingest_pkts_total 100" in lines
    # summaries carry quantiles + _sum/_count subseries
    assert any(line.startswith('cato_latency_total{quantile="0.5"}')
               for line in lines)
    assert any(line.startswith("cato_latency_total_count 2") for line in lines)
    assert any(line.startswith("cato_dispatch_latency_sum") for line in lines)
    # HELP/TYPE exactly once per family
    helps = [line.split()[2] for line in lines if line.startswith("# HELP")]
    assert len(helps) == len(set(helps))

    # the checker actually catches malformed exposition
    assert check_prometheus("# HELP a x\n# HELP a x\n# TYPE a counter\na 1\n")
    assert check_prometheus("what is this\n")
    assert check_prometheus("orphan_sample 1\n")
    bad_late = "# TYPE a counter\na 1\n# HELP a late\n"
    assert any("after samples" in p for p in check_prometheus(bad_late))


def test_exporter_requires_bind():
    ex = MetricsExporter()
    with pytest.raises(RuntimeError, match="bind"):
        ex.collect(0.0)
    ex.bind(MetricsRegistry)
    doc = ex.step(1.25)
    assert doc["now_pkts"] == 1.25 and ex.steps == 1 and ex.last is doc


# ---------------------------------------------------------------------------
# profiler: latency_p99_replayed metric
# ---------------------------------------------------------------------------


def test_profiler_latency_p99_replayed(ds):
    """The replayed tail-latency metric is pinned to the replay's own
    histogram — the profiler adds no estimation of its own."""
    from repro.traffic import TrafficProfiler

    prof = TrafficProfiler(
        ds, ("dur", "s_load", "s_bytes_mean", "s_iat_mean"),
        model="tree-fast", cost_metric="latency_p99_replayed",
        cost_mode="modeled", seed=0,
    )
    x = FeatureRep(("dur", "s_load", "s_bytes_mean"), 8)
    p99, stats = prof.replayed_latency_p99(x, prof.perf_f1(x)[1])
    assert p99 > 0
    assert p99 == stats.latency_p99_s
    assert p99 == stats.metrics.latency.percentile(99)

    r = prof(x)
    assert r.cost == p99          # lower is better: no negation
    assert 0 <= r.perf <= 1

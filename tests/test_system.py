"""End-to-end behaviour tests for the paper's system (CATO on traffic).

The integration contract, reproduced at mini scale:
  1. CATO's Pareto front on the real profiler dominates fixed-depth
     baselines (paper Fig. 5 behaviour);
  2. the estimated front approaches the exhaustive ground truth (Fig. 6);
  3. the deployed pipeline built from a Pareto point reproduces the
     profiler's measured F1 (validation property, §3.4).
"""
import numpy as np
import pytest

from repro.core import (
    CatoOptimizer, FeatureRep, SearchSpace, build_priors, hvi_ratio,
)
from repro.core.baselines import select_all
from repro.traffic import (
    MINI_FEATURE_NAMES, TrafficProfiler, extract_features, make_dataset,
)


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("iot-class", n_flows=1200, max_pkts=64, seed=5)
    prof = TrafficProfiler(ds, MINI_FEATURE_NAMES, model="rf-fast",
                           cost_metric="exec_time", cost_mode="modeled", seed=0)
    space = SearchSpace(MINI_FEATURE_NAMES, max_depth=24)
    X = extract_features(ds, MINI_FEATURE_NAMES, 24)
    priors = build_priors(space, X, ds.label)
    return ds, prof, space, priors


def test_cato_dominates_fixed_depth_all_features(setup):
    ds, prof, space, priors = setup
    res = CatoOptimizer(space, prof, priors, seed=0).run(25)
    front = res.pareto_observations()
    assert len(front) >= 2

    base = prof(select_all(space, 10))
    # some Pareto point should approach the ALL@10 baseline from below on
    # cost without giving up much F1 (tolerances sized for 25 iterations)
    assert any(
        o.cost <= base.cost * 1.05 and o.perf >= base.perf - 0.06
        for o in front
    )


def test_cato_front_quality_vs_ground_truth(setup):
    """Exhaustively enumerate a small space; CATO@20% samples gets close."""
    ds, prof, space, priors = setup
    small = SearchSpace(MINI_FEATURE_NAMES[:4], max_depth=8)
    Xs = extract_features(ds, small.feature_names, 8)
    pri = build_priors(small, Xs, ds.label)
    Yt = np.array(
        [[prof(x).cost, -prof(x).perf] for x in small.enumerate_all()]
    )
    n_budget = max(10, int(0.2 * len(Yt)))
    res = CatoOptimizer(small, prof, pri, seed=1).run(n_budget)
    Yb = np.array([o.objectives for o in res.observations])
    assert hvi_ratio(Yb, Yt) > 0.8


def test_pipeline_validates_profiler_f1(setup):
    from repro.traffic.models import macro_f1, train_traffic_model
    from repro.traffic.pipeline import build_pipeline

    ds, prof, space, priors = setup
    rep = FeatureRep(MINI_FEATURE_NAMES, 12)
    r = prof(rep)
    # rebuild the deployable pipeline exactly as the Profiler measured it
    Xtr, _ = prof.columns(rep)
    forest, _ = train_traffic_model(Xtr, prof.train_ds.label, model="rf-fast",
                                    seed=0)
    pipe = build_pipeline(rep, forest, ds.max_pkts)
    pred = pipe(prof.test_ds)
    f1 = macro_f1(prof.test_ds.label, pred)
    assert abs(f1 - r.perf) < 1e-6, "deployed pipeline must match measured perf"

"""Traffic substrate: extraction oracle, feature DAG, profiler, pipeline."""
import numpy as np
import pytest

from repro.core import FeatureRep, SearchSpace, build_priors
from repro.traffic import (
    FEATURE_NAMES, FEATURES, MINI_FEATURE_NAMES, TrafficProfiler,
    extract_features, make_dataset,
)
from repro.traffic.features import (
    modeled_extraction_cost_ns, per_packet_ops,
)


@pytest.fixture(scope="module")
def ds():
    return make_dataset("iot-class", n_flows=600, max_pkts=64, seed=3)


def test_registry_has_67_features():
    assert len(FEATURES) == 67
    assert len(MINI_FEATURE_NAMES) == 6
    assert set(MINI_FEATURE_NAMES) <= set(FEATURE_NAMES)


def test_extraction_matches_manual_oracle(ds):
    depth = 9
    names = ("s_bytes_sum", "s_bytes_mean", "s_bytes_max", "d_pkt_cnt",
             "dur", "ack_cnt", "s_ttl_min", "d_winsize_std", "s_bytes_med")
    X = extract_features(ds, names, depth)
    idx = np.arange(ds.max_pkts)[None, :]
    valid = (idx < ds.flow_len[:, None]) & (idx < depth)
    s_mask = valid & (ds.direction == 0)
    d_mask = valid & (ds.direction == 1)

    def stat(v, m, fn, empty=0.0):
        out = np.zeros(ds.n_flows)
        for i in range(ds.n_flows):
            vals = v[i][m[i]]
            out[i] = fn(vals) if len(vals) else empty
        return out

    np.testing.assert_allclose(X[:, 0], stat(ds.size, s_mask, np.sum), rtol=1e-5)
    np.testing.assert_allclose(X[:, 1], stat(ds.size, s_mask, np.mean), rtol=1e-5)
    np.testing.assert_allclose(X[:, 2], stat(ds.size, s_mask, np.max), rtol=1e-5)
    np.testing.assert_allclose(X[:, 3], d_mask.sum(1), rtol=1e-6)
    dur = stat(ds.ts, valid, np.max) - stat(ds.ts, valid, np.min)
    np.testing.assert_allclose(X[:, 4], dur, rtol=1e-4, atol=1e-5)
    ack = np.where(valid, ds.flags[:, :, 3], 0).sum(1)
    np.testing.assert_allclose(X[:, 5], ack, rtol=1e-6)
    np.testing.assert_allclose(X[:, 6], stat(ds.ttl, s_mask, np.min), rtol=1e-5)
    np.testing.assert_allclose(
        X[:, 7], stat(ds.winsize, d_mask, lambda v: np.std(v)), rtol=2e-3,
        atol=1e-2,
    )
    np.testing.assert_allclose(
        X[:, 8], stat(ds.size, s_mask, np.median), rtol=1e-5
    )


def test_depth_monotone_mask(ds):
    """Features at depth d only use the first d packets: growing depth can
    only add packets — sums are monotone."""
    X3 = extract_features(ds, ("s_bytes_sum", "ack_cnt"), 3)
    X9 = extract_features(ds, ("s_bytes_sum", "ack_cnt"), 9)
    assert (X9 >= X3 - 1e-5).all()


def test_shared_op_dedup_cheaper_than_naive():
    both = ("s_winsize_mean", "ack_cnt")   # share parse chain down to TCP
    assert per_packet_ops(both, dedup=True) < per_packet_ops(both, dedup=False)
    # cost grows with depth
    assert modeled_extraction_cost_ns(both, 50) > modeled_extraction_cost_ns(both, 5)


def test_profiler_metrics_sane(ds):
    prof = TrafficProfiler(ds, MINI_FEATURE_NAMES, model="rf-fast",
                           cost_mode="modeled", seed=0)
    x = FeatureRep(MINI_FEATURE_NAMES, 10)
    r = prof(x)
    assert 0 <= r.perf <= 1
    assert r.cost > 0
    # latency includes waiting for packets -> >> exec time
    lat = prof(x, metric="latency")
    assert lat.cost > r.cost / 1e6
    thr = prof(x, metric="throughput")
    assert thr.cost < 0  # negated throughput
    # fewer features at same depth never cost more (modeled)
    r1 = prof(FeatureRep(("s_bytes_sum",), 10))
    assert r1.cost <= r.cost


def test_profiler_caches(ds):
    prof = TrafficProfiler(ds, MINI_FEATURE_NAMES, model="rf-fast", seed=0)
    x = FeatureRep(("dur", "s_load"), 5)
    prof(x)
    n = prof.n_profile_calls
    prof(x)
    assert prof.n_profile_calls == n


def test_priors_favor_informative_features(ds):
    space = SearchSpace(MINI_FEATURE_NAMES, max_depth=50)
    X = extract_features(ds, MINI_FEATURE_NAMES, 50)
    priors = build_priors(space, X, ds.label)
    assert priors.feature_probs.shape == (6,)
    assert (priors.feature_probs >= 0).all() and (priors.feature_probs <= 1).all()
    # depth prior decays
    assert priors.depth_pmf[0] > priors.depth_pmf[-1]


def test_end_to_end_pipeline_artifact(ds):
    from repro.traffic.models import train_traffic_model, macro_f1
    from repro.traffic.pipeline import build_pipeline

    rep = FeatureRep(MINI_FEATURE_NAMES, 12)
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="rf-fast", seed=0)
    pipe = build_pipeline(rep, forest, ds.max_pkts)
    pred = pipe(ds)
    f1 = macro_f1(ds.label, pred)
    assert f1 > 0.2  # trained on itself; just proves the artifact works
    probs = pipe.probabilities(ds)
    np.testing.assert_allclose(probs.sum(1), 1.0, atol=1e-3)


def test_pipeline_kernel_and_ref_paths_agree(ds):
    """build_pipeline(use_kernel=False) routes through ref.forest_infer_ref;
    it must match the Pallas ops.forest_infer path on the same forest."""
    from repro.traffic.models import train_traffic_model
    from repro.traffic.pipeline import build_pipeline

    rep = FeatureRep(MINI_FEATURE_NAMES + ("ack_cnt", "d_winsize_std"), 9)
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="rf-fast", seed=1)
    pk = build_pipeline(rep, forest, ds.max_pkts, use_kernel=True)
    pr = build_pipeline(rep, forest, ds.max_pkts, use_kernel=False)
    np.testing.assert_allclose(
        pk.probabilities(ds), pr.probabilities(ds), atol=1e-5
    )
    assert (pk(ds) == pr(ds)).all()


def test_truncate_view_preserves_extraction(ds):
    """Extraction at depth d over truncated tensors matches the full-width
    dataset — the contract the streaming flow table's storage relies on."""
    depth = 10
    names = ("s_bytes_sum", "dur", "ack_cnt", "s_iat_mean")
    full = extract_features(ds, names, depth)
    trunc = extract_features(ds.truncate(depth), names, depth)
    np.testing.assert_array_equal(full, trunc)
